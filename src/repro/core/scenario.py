"""Unified, validated `Scenario` spec — the single entry point to the repo.

The paper's pipeline is "describe an operating point -> predict with closed
forms -> validate with the simulator -> act with Algorithm 1". Before this
module, each of those consumers re-assembled the operating point its own way
(tuples into :mod:`latency`, closures into :mod:`crossover`, ``ServiceDist``
objects into :mod:`simulation`, hand-built ``EdgeServerState`` into
:mod:`manager`). A :class:`Scenario` is the one declarative description all
four consume:

    scn = Scenario(workload=..., device=..., network=..., edges=(...,))
    analytic(scn)            # closed-form LatencyBreakdown per strategy
    simulate(scn, seed=0)    # discrete-event validation of the same spec
    crossovers(scn, "bandwidth")   # quantitative crossover queries
    scn.manager().decide(scn.workload, scn.snapshot(), scn.edge_states())

Validation is eager and FastSim-style ("fail before running"): a bad spec
raises :class:`ScenarioError` naming the offending field at construction
time, not ``inf``/NaN half-way through a sweep. The existing low-level
functions remain the stable kernel layer underneath; nothing here re-derives
queueing math.
"""

from __future__ import annotations

import re
from dataclasses import dataclass, fields, replace
from typing import Any, Iterable, Mapping, Sequence

import numpy as np

from . import simulation as S
from .crossover import (
    Crossover,
    arrival_rate_crossovers,
    bandwidth_crossover,
    smallest_true,
    solve_crossover,
)
from .latency import (
    LatencyBreakdown,
    NetworkPath,
    ServiceModel,
    Tier,
    Workload,
    edge_offload_latency,
    on_device_latency,
)
from .manager import AdaptiveOffloadManager, EdgeServerState
from .multitenant import AggregateLoad, TenantStream, aggregate_streams, multitenant_edge_latency
from .tail import (
    Station,
    mixture_station,
    offload_stations,
    proc_station,
    sojourn_quantile,
)
from .telemetry import TelemetrySnapshot

__all__ = [
    "ScenarioError",
    "EdgeSpec",
    "Scenario",
    "ClusterSpec",
    "ClientClass",
    "MeanFieldSpec",
    "ScenarioPrediction",
    "analytic",
    "analytic_tail",
    "tail_stations",
    "tier_station",
    "simulate",
    "crossovers",
    "implied_service_var",
    "parse_strategy",
]

# ServiceModel -> repro.core.tail kind code (identical numbering to
# repro.fleet.batch.MODEL_CODES, asserted in tests)
_TAIL_KINDS = {
    ServiceModel.DETERMINISTIC: 0,
    ServiceModel.EXPONENTIAL: 1,
    ServiceModel.GENERAL: 2,
}


def implied_service_var(tier: Tier) -> float:
    """Var[s] implied by the tier's queueing formulation.

    DETERMINISTIC service has zero variance, EXPONENTIAL has mean^2, GENERAL
    carries its explicit ``service_var``. Mixture math (multi-tenant
    aggregates, Algorithm-1 M/G/1 inputs) must use this — feeding ``0`` for
    an exponential tier would silently downgrade M/M/1 to M/D/1.
    """
    if tier.service_model is ServiceModel.EXPONENTIAL:
        return tier.service_time_s**2
    if tier.service_model is ServiceModel.GENERAL:
        return tier.service_var
    return 0.0


class ScenarioError(ValueError):
    """A scenario spec failed eager validation. ``field`` names the culprit."""

    def __init__(self, field_path: str, message: str):
        self.field = field_path
        super().__init__(f"{field_path}: {message}")


def _require(cond: bool, field_path: str, message: str) -> None:
    if not cond:
        raise ScenarioError(field_path, message)


def _coerce_model(value: Any, field_path: str) -> ServiceModel:
    if isinstance(value, ServiceModel):
        return value
    try:
        return ServiceModel(value)
    except ValueError:
        known = ", ".join(m.value for m in ServiceModel)
        raise ScenarioError(
            field_path, f"unknown service model {value!r} (known: {known})"
        ) from None


def _validate_tier(tier: Tier, field_path: str) -> Tier:
    _require(isinstance(tier, Tier), field_path, f"expected a Tier, got {type(tier).__name__}")
    _require(tier.service_time_s > 0, f"{field_path}.service_time_s",
             f"must be positive, got {tier.service_time_s!r}")
    _require(tier.parallelism_k > 0, f"{field_path}.parallelism_k",
             f"must be positive, got {tier.parallelism_k!r}")
    _require(tier.service_var >= 0, f"{field_path}.service_var",
             f"must be non-negative, got {tier.service_var!r}")
    model = _coerce_model(tier.service_model, f"{field_path}.service_model")
    return tier if model is tier.service_model else replace(tier, service_model=model)


# ---------------------------------------------------------------------------
# EdgeSpec
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class EdgeSpec:
    """One edge server: its tier plus the background tenants it already hosts.

    ``background`` are the *other* applications multiplexed onto this edge
    (paper §3.4); the scenario's own workload stream is added automatically
    wherever the aggregate matters. ``bandwidth_Bps`` overrides the
    scenario-level network path for this edge only (``0.0`` would be invalid,
    not "unset" — only ``None`` means "use the shared path").
    """

    tier: Tier
    background: tuple[TenantStream, ...] = ()
    bandwidth_Bps: float | None = None

    def __post_init__(self):
        if not isinstance(self.background, tuple):
            object.__setattr__(self, "background", tuple(self.background))

    @property
    def name(self) -> str:
        return self.tier.name

    def own_stream(self, wl: Workload) -> TenantStream:
        """The scenario workload's own stream as this edge would see it.

        Variance is the one the tier's service model implies (s^2 for
        EXPONENTIAL, 0 for DETERMINISTIC), so adding an epsilon-rate
        background tenant leaves the M/M/1 prediction continuous instead of
        discontinuously dropping to the M/D/1 form.
        """
        return TenantStream(
            arrival_rate=wl.arrival_rate,
            service_mean_s=self.tier.service_time_s,
            service_var=implied_service_var(self.tier),
            name=wl.name,
        )

    def aggregate(self, wl: Workload) -> AggregateLoad:
        """Mixture moments of background + the scenario's own stream."""
        return aggregate_streams((self.own_stream(wl),) + self.background)

    def to_state(self, wl: Workload) -> EdgeServerState:
        """The Algorithm-1 input (``EdgeServerState``) for this edge.

        Mirrors ``serving.gateway.EdgeHandle.state``: the aggregate arrival
        rate and mixture variance include the workload's own stream, while
        ``service_time_s`` stays the workload's own service time on this tier
        (Alg. 1 line 6 uses s_edge of THIS workload).
        """
        agg = self.aggregate(wl)
        return EdgeServerState(
            name=self.tier.name,
            service_rate=agg.service_rate,
            arrival_rate=agg.arrival_rate,
            service_time_s=self.tier.service_time_s,
            service_var=agg.service_var,
            parallelism_k=self.tier.parallelism_k,
            bandwidth_Bps=self.bandwidth_Bps,
        )


# ---------------------------------------------------------------------------
# Scenario
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Scenario:
    """A complete, validated operating point (device + edges + network + load).

    Frozen and eagerly validated: positivity of every rate/size, per-queue
    stability (device proc, device NIC, each edge's aggregate proc + NIC),
    and service-model sanity all fail at construction with the offending
    field named. Set ``allow_unstable=True`` for specs that deliberately
    cross stability boundaries (saturation studies, wide sweeps) — the
    closed forms then return ``inf`` there, exactly as the kernel layer does.
    """

    workload: Workload
    device: Tier
    network: NetworkPath
    edges: tuple[EdgeSpec, ...] = ()
    return_results: bool = True
    allow_unstable: bool = False
    name: str = "scenario"

    def __post_init__(self):
        if not isinstance(self.edges, tuple):
            object.__setattr__(self, "edges", tuple(self.edges))
        self._validate()

    # -- validation (FastSim-style: fail before running) ---------------------
    def _validate(self) -> None:
        wl, dev, net = self.workload, self.device, self.network
        _require(isinstance(wl, Workload), "workload",
                 f"expected a Workload, got {type(wl).__name__}")
        _require(wl.arrival_rate > 0, "workload.arrival_rate",
                 f"must be positive, got {wl.arrival_rate!r}")
        _require(wl.req_bytes > 0, "workload.req_bytes",
                 f"must be positive, got {wl.req_bytes!r}")
        _require(wl.res_bytes >= 0, "workload.res_bytes",
                 f"must be non-negative, got {wl.res_bytes!r}")
        _require(isinstance(net, NetworkPath), "network",
                 f"expected a NetworkPath, got {type(net).__name__}")
        _require(float(np.asarray(net.bandwidth_Bps)) > 0, "network.bandwidth_Bps",
                 f"must be positive, got {net.bandwidth_Bps!r}")

        coerced = _validate_tier(dev, "device")
        if coerced is not dev:
            object.__setattr__(self, "device", coerced)

        new_edges = []
        for i, e in enumerate(self.edges):
            path = f"edges[{i}]"
            _require(isinstance(e, EdgeSpec), path,
                     f"expected an EdgeSpec, got {type(e).__name__}")
            tier = _validate_tier(e.tier, f"{path}.tier")
            if e.bandwidth_Bps is not None:
                _require(e.bandwidth_Bps > 0, f"{path}.bandwidth_Bps",
                         f"must be positive (use None for 'unset'), got {e.bandwidth_Bps!r}")
            for j, t in enumerate(e.background):
                bpath = f"{path}.background[{j}]"
                _require(t.arrival_rate > 0, f"{bpath}.arrival_rate",
                         f"must be positive, got {t.arrival_rate!r}")
                _require(t.service_mean_s > 0, f"{bpath}.service_mean_s",
                         f"must be positive, got {t.service_mean_s!r}")
                _require(t.service_var >= 0, f"{bpath}.service_var",
                         f"must be non-negative, got {t.service_var!r}")
            new_edges.append(e if tier is e.tier else replace(e, tier=tier))
        if any(a is not b for a, b in zip(new_edges, self.edges)):
            object.__setattr__(self, "edges", tuple(new_edges))

        if not self.allow_unstable:
            self._validate_stability()

    def _validate_stability(self) -> None:
        wl, dev = self.workload, self.device
        lam = wl.arrival_rate
        kmu_dev = dev.parallelism_k / dev.service_time_s
        _require(lam < kmu_dev, "device",
                 f"unstable: arrival_rate {lam} >= k*mu {kmu_dev:.4g} "
                 "(set allow_unstable=True to permit)")
        for i, e in enumerate(self.edges):
            path = f"edges[{i}]"
            net = self.network_for(e)
            b = float(np.asarray(net.bandwidth_Bps))
            _require(lam < b / wl.req_bytes, f"{path}.bandwidth_Bps" if e.bandwidth_Bps
                     is not None else "network.bandwidth_Bps",
                     f"device NIC unstable: arrival_rate {lam} >= B/D_req "
                     f"{b / wl.req_bytes:.4g} (set allow_unstable=True to permit)")
            agg = e.aggregate(wl)
            kmu_e = e.tier.parallelism_k * agg.service_rate
            _require(agg.arrival_rate < kmu_e, path,
                     f"unstable: aggregate arrival_rate {agg.arrival_rate:.4g} >= "
                     f"k*mu {kmu_e:.4g} (set allow_unstable=True to permit)")
            if self.return_results and wl.res_bytes > 0:
                _require(agg.arrival_rate < b / wl.res_bytes, path,
                         f"edge NIC unstable: aggregate arrival_rate "
                         f"{agg.arrival_rate:.4g} >= B/D_res {b / wl.res_bytes:.4g} "
                         "(set allow_unstable=True to permit)")

    # -- serialisation --------------------------------------------------------
    def to_dict(self) -> dict:
        """Plain-JSON dict; ``from_dict(to_dict(scn)) == scn`` (``Tier.meta``
        is session-local and intentionally not serialised)."""

        def tier_d(t: Tier) -> dict:
            return {
                "name": t.name,
                "service_time_s": t.service_time_s,
                "parallelism_k": t.parallelism_k,
                "service_model": t.service_model.value,
                "service_var": t.service_var,
            }

        return {
            "name": self.name,
            "workload": {
                "arrival_rate": self.workload.arrival_rate,
                "req_bytes": self.workload.req_bytes,
                "res_bytes": self.workload.res_bytes,
                "name": self.workload.name,
            },
            "device": tier_d(self.device),
            "network": {"bandwidth_Bps": self.network.bandwidth_Bps},
            "edges": [
                {
                    "tier": tier_d(e.tier),
                    "background": [
                        {
                            "arrival_rate": t.arrival_rate,
                            "service_mean_s": t.service_mean_s,
                            "service_var": t.service_var,
                            "name": t.name,
                        }
                        for t in e.background
                    ],
                    "bandwidth_Bps": e.bandwidth_Bps,
                }
                for e in self.edges
            ],
            "return_results": self.return_results,
            "allow_unstable": self.allow_unstable,
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "Scenario":
        """Inverse of :meth:`to_dict`. Missing required fields and unknown
        service-model strings raise :class:`ScenarioError` naming the field."""

        def get(m: Mapping, key: str, path: str):
            try:
                return m[key]
            except (KeyError, TypeError):
                raise ScenarioError(f"{path}.{key}" if path else key,
                                    "missing required field") from None

        def tier_f(td: Mapping, path: str) -> Tier:
            return Tier(
                name=td.get("name", "tier"),
                service_time_s=get(td, "service_time_s", path),
                parallelism_k=td.get("parallelism_k", 1.0),
                service_model=_coerce_model(td.get("service_model", "md1"),
                                            f"{path}.service_model"),
                service_var=td.get("service_var", 0.0),
            )

        wl_d = get(d, "workload", "")
        dev_d = get(d, "device", "")
        net_d = get(d, "network", "")
        return cls(
            workload=Workload(
                arrival_rate=get(wl_d, "arrival_rate", "workload"),
                req_bytes=get(wl_d, "req_bytes", "workload"),
                res_bytes=get(wl_d, "res_bytes", "workload"),
                name=wl_d.get("name", "workload"),
            ),
            device=tier_f(dev_d, "device"),
            network=NetworkPath(bandwidth_Bps=get(net_d, "bandwidth_Bps", "network")),
            edges=tuple(
                EdgeSpec(
                    tier=tier_f(get(ed, "tier", f"edges[{i}]"), f"edges[{i}].tier"),
                    background=tuple(
                        TenantStream(
                            arrival_rate=get(td, "arrival_rate",
                                             f"edges[{i}].background[{j}]"),
                            service_mean_s=get(td, "service_mean_s",
                                               f"edges[{i}].background[{j}]"),
                            service_var=td.get("service_var", 0.0),
                            name=td.get("name", "tenant"),
                        )
                        for j, td in enumerate(ed.get("background", []))
                    ),
                    bandwidth_Bps=ed.get("bandwidth_Bps"),
                )
                for i, ed in enumerate(d.get("edges", []))
            ),
            return_results=d.get("return_results", True),
            allow_unstable=d.get("allow_unstable", False),
            name=d.get("name", "scenario"),
        )

    # -- sweeps ---------------------------------------------------------------
    def replaced(self, field_path: str, value: Any) -> "Scenario":
        """A copy with the dotted/indexed ``field_path`` set to ``value``
        (e.g. ``"network.bandwidth_Bps"``, ``"edges[0].tier.service_time_s"``).
        Re-validates eagerly like any construction."""
        parts = _parse_path(field_path)
        return _set_path(self, parts, value, field_path)

    def sweep(self, field_path: str, values: Iterable) -> list["Scenario"]:
        """A family of scenarios varying one field — the vectorised form every
        figure-style experiment uses. ``values`` may be any iterable, including
        numpy arrays (elements are coerced to plain Python numbers so swept
        specs stay exactly JSON-round-trippable). Sweeps routinely cross
        stability boundaries on purpose, so swept copies carry
        ``allow_unstable=True`` and the closed forms report ``inf`` past
        saturation."""
        base = self if self.allow_unstable else replace(self, allow_unstable=True)
        return [base.replaced(field_path, _coerce_value(v)) for v in values]

    def grid(self, axes: Mapping[str, Iterable]) -> list["Scenario"]:
        """Cartesian multi-axis sweep: one scenario per combination of axis
        values, in C order (last axis fastest — matching
        ``np.meshgrid(..., indexing="ij")`` raveled, and therefore row ``i`` of
        ``repro.fleet.ScenarioBatch.from_sweep(scn, axes)``). Like
        :meth:`sweep`, grid points carry ``allow_unstable=True``."""
        import itertools

        base = self if self.allow_unstable else replace(self, allow_unstable=True)
        paths = list(axes)
        value_lists = [[_coerce_value(v) for v in axes[p]] for p in paths]
        for p, vals in zip(paths, value_lists):
            _require(len(vals) > 0, p, "grid axis must have at least one value")
        out = []
        for combo in itertools.product(*value_lists):
            scn = base
            for p, v in zip(paths, combo):
                scn = scn.replaced(p, v)
            out.append(scn)
        return out

    # -- consumer constructors -------------------------------------------------
    def network_for(self, edge: EdgeSpec) -> NetworkPath:
        return (
            self.network
            if edge.bandwidth_Bps is None
            else NetworkPath(bandwidth_Bps=edge.bandwidth_Bps)
        )

    def edge_states(self) -> tuple[EdgeServerState, ...]:
        """Algorithm-1 inputs for every edge, derived from this one spec."""
        return tuple(e.to_state(self.workload) for e in self.edges)

    def snapshot(
        self,
        time_s: float = 0.0,
        *,
        bandwidth_Bps: float | None = None,
        arrival_rate: float | None = None,
    ) -> TelemetrySnapshot:
        """A telemetry snapshot of this operating point (overridable for
        replaying schedules like the paper's Fig. 6 bandwidth trace)."""
        return TelemetrySnapshot(
            time_s=time_s,
            lam_dev=self.workload.arrival_rate if arrival_rate is None else arrival_rate,
            bandwidth_Bps=float(np.asarray(
                self.network.bandwidth_Bps if bandwidth_Bps is None else bandwidth_Bps
            )),
        )

    def manager(self, **kwargs) -> AdaptiveOffloadManager:
        """An :class:`AdaptiveOffloadManager` for this scenario's device tier
        (``hysteresis=``/``tail_z=`` pass through; ``return_results``
        defaults to this scenario's setting so Algorithm 1 models the same
        network legs as :func:`analytic`)."""
        kwargs.setdefault("return_results", self.return_results)
        return AdaptiveOffloadManager(self.device, **kwargs)

    # -- method sugar for the module-level consumers ---------------------------
    def analytic(self) -> "ScenarioPrediction":
        return analytic(self)

    def analytic_tail(self, q: float, *, method: str = "euler") -> dict[str, float]:
        return analytic_tail(self, q, method=method)

    def simulate(self, strategy: str | None = None, **kwargs) -> S.SimResult:
        return simulate(self, strategy, **kwargs)

    def crossovers(self, axis: str, **kwargs) -> Crossover:
        return crossovers(self, axis, **kwargs)


# ---------------------------------------------------------------------------
# ClusterSpec: N clients sharing one edge pool (closed-loop §6 setting)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ClusterSpec:
    """N clients contending for the ``base`` scenario's edge servers.

    ``base`` is the per-client template: its device tier, workload payloads,
    network path, and ``edges`` (the shared pool every client may offload to).
    ``arrival_scale`` optionally gives each client its own multiplier on the
    template arrival rate (empty = homogeneous fleet). The closed-loop
    semantics — each client's offload decision adds its stream to the chosen
    edge's aggregate, which every other client then observes — live in
    :mod:`repro.fleet.cluster`; this spec is the validated, serialisable
    description they consume, exactly as :class:`Scenario` is for the
    open-loop paths.
    """

    base: Scenario
    n_clients: int
    arrival_scale: tuple[float, ...] = ()
    name: str = "cluster"

    def __post_init__(self):
        if not isinstance(self.arrival_scale, tuple):
            object.__setattr__(self, "arrival_scale", tuple(self.arrival_scale))
        _require(isinstance(self.base, Scenario), "base",
                 f"expected a Scenario, got {type(self.base).__name__}")
        _require(bool(self.base.edges), "base.edges",
                 "a cluster needs at least one shared edge server")
        _require(
            isinstance(self.n_clients, (int, np.integer))
            and not isinstance(self.n_clients, bool)
            and self.n_clients >= 1,
            "n_clients", f"must be a positive integer, got {self.n_clients!r}")
        if self.arrival_scale:
            _require(len(self.arrival_scale) == self.n_clients, "arrival_scale",
                     f"length {len(self.arrival_scale)} != n_clients {self.n_clients}")
            for i, s in enumerate(self.arrival_scale):
                _require(bool(np.isfinite(s)) and s > 0, f"arrival_scale[{i}]",
                         f"must be positive and finite, got {s!r}")

    @property
    def n_edges(self) -> int:
        return len(self.base.edges)

    def arrival_rates(self) -> np.ndarray:
        """(N,) per-client true arrival rates (template rate x scale)."""
        scale = np.asarray(self.arrival_scale, dtype=np.float64) \
            if self.arrival_scale else np.ones(self.n_clients)
        return self.base.workload.arrival_rate * scale

    def client(self, i: int) -> Scenario:
        """Client ``i``'s open-loop view (its own arrival rate, the shared
        edge pool, no other clients). Carries ``allow_unstable=True`` — the
        whole point of the closed loop is that the pool can saturate when
        everyone piles onto one edge, and the closed forms report that as
        ``inf`` rather than refusing the spec."""
        if not 0 <= i < self.n_clients:
            raise ScenarioError("n_clients", f"client index {i} out of range "
                                f"(n_clients {self.n_clients})")
        scn = self.base if self.base.allow_unstable else \
            replace(self.base, allow_unstable=True)
        lam = float(self.arrival_rates()[i])
        if lam != scn.workload.arrival_rate:
            scn = scn.replaced("workload.arrival_rate", lam)
        return scn

    def to_dict(self) -> dict:
        """Plain-JSON dict; ``from_dict(to_dict(spec)) == spec``."""
        return {
            "name": self.name,
            "base": self.base.to_dict(),
            "n_clients": int(self.n_clients),
            "arrival_scale": list(self.arrival_scale),
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "ClusterSpec":
        try:
            base = d["base"]
            n_clients = d["n_clients"]
        except (KeyError, TypeError):
            missing = "base" if not isinstance(d, Mapping) or "base" not in d \
                else "n_clients"
            raise ScenarioError(missing, "missing required field") from None
        return cls(
            base=Scenario.from_dict(base),
            n_clients=int(n_clients),
            arrival_scale=tuple(float(s) for s in d.get("arrival_scale", [])),
            name=d.get("name", "cluster"),
        )


# ---------------------------------------------------------------------------
# ClientClass / MeanFieldSpec: client-*class* aggregation for mean-field scale
# ---------------------------------------------------------------------------


def _tier_to_dict(t: Tier) -> dict:
    return {
        "name": t.name,
        "service_time_s": t.service_time_s,
        "parallelism_k": t.parallelism_k,
        "service_model": t.service_model.value,
        "service_var": t.service_var,
    }


def _tier_from_dict(td: Mapping, path: str) -> Tier:
    try:
        s = td["service_time_s"]
    except (KeyError, TypeError):
        raise ScenarioError(f"{path}.service_time_s", "missing required field") \
            from None
    return Tier(
        name=td.get("name", "tier"),
        service_time_s=s,
        parallelism_k=td.get("parallelism_k", 1.0),
        service_model=_coerce_model(td.get("service_model", "md1"),
                                    f"{path}.service_model"),
        service_var=td.get("service_var", 0.0),
    )


@dataclass(frozen=True)
class ClientClass:
    """One homogeneous cohort of a mean-field fleet.

    A class is a (device tier, arrival-rate band, bandwidth-trace band)
    bucket: ``n_clients`` statistically identical clients whose arrival rate
    is ``arrival_scale`` x the base workload rate, whose shared-path
    bandwidth is ``bandwidth_scale`` x the base network path (the
    "bandwidth-trace class" — well-connected vs cellular cohorts), and whose
    device tier is ``device`` (``None`` = the base scenario's device). The
    mean-field layer evolves one offload-fraction row per class instead of
    one decision per client, which is what takes the closed loop from tens
    of clients to millions.
    """

    n_clients: int
    arrival_scale: float = 1.0
    bandwidth_scale: float = 1.0
    device: Tier | None = None
    name: str = "class"

    def __post_init__(self):
        _require(
            isinstance(self.n_clients, (int, np.integer))
            and not isinstance(self.n_clients, bool)
            and self.n_clients >= 1,
            "n_clients", f"must be a positive integer, got {self.n_clients!r}")
        for field_name in ("arrival_scale", "bandwidth_scale"):
            v = getattr(self, field_name)
            _require(bool(np.isfinite(v)) and v > 0, field_name,
                     f"must be positive and finite, got {v!r}")
        if self.device is not None:
            coerced = _validate_tier(self.device, "device")
            if coerced is not self.device:
                object.__setattr__(self, "device", coerced)

    def to_dict(self) -> dict:
        return {
            "name": self.name,
            "n_clients": int(self.n_clients),
            "arrival_scale": float(self.arrival_scale),
            "bandwidth_scale": float(self.bandwidth_scale),
            "device": None if self.device is None else _tier_to_dict(self.device),
        }

    @classmethod
    def from_dict(cls, d: Mapping, path: str = "classes[?]") -> "ClientClass":
        try:
            n = d["n_clients"]
        except (KeyError, TypeError):
            raise ScenarioError(f"{path}.n_clients", "missing required field") \
                from None
        dev = d.get("device")
        return cls(
            n_clients=int(n),
            arrival_scale=float(d.get("arrival_scale", 1.0)),
            bandwidth_scale=float(d.get("bandwidth_scale", 1.0)),
            device=None if dev is None else _tier_from_dict(dev, f"{path}.device"),
            name=d.get("name", "class"),
        )


@dataclass(frozen=True)
class MeanFieldSpec:
    """A fleet described by client *classes* instead of individual clients.

    ``base`` is the shared template exactly as in :class:`ClusterSpec` (its
    ``edges`` are the shared pool every class may offload to); ``classes``
    partition the fleet into homogeneous cohorts. The mean-field semantics —
    per-class offload fractions whose rate-weighted sum is the endogenous
    edge load — live in :mod:`repro.fleet.meanfield`; this spec is the
    validated, serialisable description they consume.

    For small fleets the spec expands to the exact per-client
    :class:`ClusterSpec` via :meth:`to_cluster`, which is what the
    mean-field-vs-exact validation gate runs on.
    """

    base: Scenario
    classes: tuple[ClientClass, ...] = ()
    name: str = "meanfield"

    def __post_init__(self):
        if not isinstance(self.classes, tuple):
            object.__setattr__(self, "classes", tuple(self.classes))
        _require(isinstance(self.base, Scenario), "base",
                 f"expected a Scenario, got {type(self.base).__name__}")
        _require(bool(self.base.edges), "base.edges",
                 "a mean-field fleet needs at least one shared edge server")
        _require(bool(self.classes), "classes",
                 "a mean-field fleet needs at least one client class")
        for i, c in enumerate(self.classes):
            _require(isinstance(c, ClientClass), f"classes[{i}]",
                     f"expected a ClientClass, got {type(c).__name__}")

    @property
    def n_classes(self) -> int:
        return len(self.classes)

    @property
    def n_edges(self) -> int:
        return len(self.base.edges)

    @property
    def n_total(self) -> int:
        """Total clients across all classes (the fleet the fractions model)."""
        return int(sum(c.n_clients for c in self.classes))

    def class_counts(self) -> np.ndarray:
        """(C,) clients per class."""
        return np.array([c.n_clients for c in self.classes], dtype=np.float64)

    def arrival_rates(self) -> np.ndarray:
        """(C,) per-client true arrival rate of each class."""
        return self.base.workload.arrival_rate * np.array(
            [c.arrival_scale for c in self.classes], dtype=np.float64)

    def bandwidth_Bps(self, base_Bps: float | None = None) -> np.ndarray:
        """(C,) per-client shared-path bandwidth of each class — the base
        network path (or an override, e.g. one epoch of a trace) times each
        class's ``bandwidth_scale``."""
        b = float(np.asarray(self.base.network.bandwidth_Bps)) \
            if base_Bps is None else float(base_Bps)
        return b * np.array(
            [c.bandwidth_scale for c in self.classes], dtype=np.float64)

    def device_tier(self, c: int) -> Tier:
        """Class ``c``'s device tier (its override, or the base device)."""
        cl = self.classes[c]
        return self.base.device if cl.device is None else cl.device

    def class_index(self) -> np.ndarray:
        """(n_total,) expanded client -> class map, class-major order —
        matches :meth:`to_cluster`'s client ordering."""
        return np.repeat(np.arange(self.n_classes),
                         [c.n_clients for c in self.classes])

    def to_cluster(self) -> ClusterSpec:
        """The exact per-client :class:`ClusterSpec` this spec aggregates.

        Clients are laid out class-major (all of class 0, then class 1, ...,
        matching :meth:`class_index`). Per-class ``bandwidth_scale`` expands
        through :meth:`bandwidth_Bps` as a per-client array override to
        ``solve_equilibrium``; per-class ``device`` overrides cannot be
        expressed in a single-device-tier :class:`ClusterSpec` and are
        refused loudly rather than silently dropped.
        """
        for i, c in enumerate(self.classes):
            _require(c.device is None or c.device == self.base.device,
                     f"classes[{i}].device",
                     "per-class device tiers have no exact ClusterSpec "
                     "equivalent (the exact solver models one shared device "
                     "tier); compare such specs analytically instead")
        scale = np.repeat([c.arrival_scale for c in self.classes],
                          [c.n_clients for c in self.classes])
        return ClusterSpec(
            base=self.base,
            n_clients=self.n_total,
            arrival_scale=tuple(float(s) for s in scale),
            name=f"{self.name}-exact",
        )

    def to_dict(self) -> dict:
        """Plain-JSON dict; ``from_dict(to_dict(spec)) == spec``."""
        return {
            "name": self.name,
            "base": self.base.to_dict(),
            "classes": [c.to_dict() for c in self.classes],
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "MeanFieldSpec":
        try:
            base = d["base"]
            classes = d["classes"]
        except (KeyError, TypeError):
            missing = "base" if not isinstance(d, Mapping) or "base" not in d \
                else "classes"
            raise ScenarioError(missing, "missing required field") from None
        return cls(
            base=Scenario.from_dict(base),
            classes=tuple(ClientClass.from_dict(cd, f"classes[{i}]")
                          for i, cd in enumerate(classes)),
            name=d.get("name", "meanfield"),
        )


# ---------------------------------------------------------------------------
# field-path parsing for replaced()/sweep()
# ---------------------------------------------------------------------------

_PATH_TOKEN = re.compile(r"([A-Za-z_][A-Za-z_0-9]*)((?:\[\d+\])*)$")


def _coerce_value(v: Any) -> Any:
    """numpy scalars -> plain Python numbers (keeps to_dict JSON-clean)."""
    return v.item() if isinstance(v, np.generic) else v


def _parse_path(field_path: str) -> list:
    parts: list = []
    for token in field_path.split("."):
        m = _PATH_TOKEN.match(token)
        if not m:
            raise ScenarioError(field_path, f"malformed field path segment {token!r}")
        parts.append(m.group(1))
        for idx in re.findall(r"\[(\d+)\]", m.group(2)):
            parts.append(int(idx))
    return parts


def _set_path(obj: Any, parts: Sequence, value: Any, full_path: str) -> Any:
    if not parts:
        return value
    head, rest = parts[0], parts[1:]
    if isinstance(head, int):
        seq = list(obj)
        if not 0 <= head < len(seq):
            raise ScenarioError(full_path, f"index {head} out of range (len {len(seq)})")
        seq[head] = _set_path(seq[head], rest, value, full_path)
        return tuple(seq)
    if not hasattr(obj, head) or head not in {f.name for f in fields(obj)}:
        raise ScenarioError(full_path, f"{type(obj).__name__} has no field {head!r}")
    return replace(obj, **{head: _set_path(getattr(obj, head), rest, value, full_path)})


# ---------------------------------------------------------------------------
# analytic(scn): closed-form prediction per strategy
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ScenarioPrediction:
    """Closed-form :class:`LatencyBreakdown` per strategy of one scenario.

    Keys are ``"on_device"`` and ``"edge[i]"`` (matching
    ``Decision.target_name``); ``best_strategy`` is the analytic argmin.
    """

    breakdowns: dict[str, LatencyBreakdown]

    def __getitem__(self, strategy: str) -> LatencyBreakdown:
        return self.breakdowns[strategy]

    def __iter__(self):
        return iter(self.breakdowns)

    def items(self):
        return self.breakdowns.items()

    def totals(self) -> dict[str, float]:
        return {k: float(np.asarray(b.total)) for k, b in self.breakdowns.items()}

    @property
    def best_strategy(self) -> str:
        totals = self.totals()
        return min(totals, key=totals.get)

    @property
    def best(self) -> LatencyBreakdown:
        return self.breakdowns[self.best_strategy]


def analytic(scn: Scenario) -> ScenarioPrediction:
    """Paper Eq. 1/2 (+ Lemma 3.2 multi-tenant form) for every strategy.

    Wraps the kernel layer exactly: ``on_device_latency`` for the device,
    ``edge_offload_latency`` for a dedicated edge, and
    ``multitenant_edge_latency`` when the edge hosts background tenants.
    """
    out: dict[str, LatencyBreakdown] = {
        "on_device": on_device_latency(scn.workload, scn.device, breakdown=True)
    }
    for i, e in enumerate(scn.edges):
        net = scn.network_for(e)
        if e.background:
            b = multitenant_edge_latency(
                scn.workload, e.tier, net,
                (e.own_stream(scn.workload),) + e.background,
                return_results=scn.return_results, breakdown=True,
            )
        else:
            b = edge_offload_latency(
                scn.workload, e.tier, net,
                return_results=scn.return_results, breakdown=True,
            )
        out[f"edge[{i}]"] = b
    return ScenarioPrediction(out)


# ---------------------------------------------------------------------------
# analytic_tail(scn, q): closed-form sojourn quantiles per strategy
# ---------------------------------------------------------------------------


def tier_station(tier: Tier, lam: float) -> Station:
    """A :mod:`repro.core.tail` processing station for ``tier`` under arrival
    rate ``lam`` — the service-model dispatch (M/D/1 / M/M/1 / M/G/1 on the
    paper's k*mu aggregation) in distributional form."""
    return proc_station(lam, _TAIL_KINDS[tier.service_model],
                        tier.service_time_s, tier.service_var, tier.parallelism_k)


def tail_stations(scn: Scenario, strategy: str | None = None) -> tuple[Station, ...]:
    """The Fig. 1 tandem of ``strategy``'s path as :mod:`repro.core.tail`
    stations: on-device is the single processing queue; ``edge[j]`` is device
    NIC -> edge proc (own model, or the §3.4 gamma-matched mixture when the
    edge hosts background tenants) -> return NIC. The mean of the composed
    distribution equals :func:`analytic`'s total on the same path, so tails
    and means can never disagree about the operating point."""
    strategy, j = _resolve_strategy(scn, strategy)
    wl = scn.workload
    if j < 0:
        return (tier_station(scn.device, wl.arrival_rate),)
    e = scn.edges[j]
    b = float(np.asarray(scn.network_for(e).bandwidth_Bps))
    if e.background:
        agg = e.aggregate(wl)
        proc = mixture_station(agg.arrival_rate, agg.service_mean_s,
                               agg.service_var, e.tier.parallelism_k)
    else:
        proc = tier_station(e.tier, wl.arrival_rate)
    return offload_stations(wl.arrival_rate, wl.req_bytes, wl.res_bytes, b,
                            proc, return_results=scn.return_results)


def analytic_tail(scn: Scenario, q: float, *, method: str = "euler") -> dict[str, float]:
    """The q-quantile (q in (0, 1)) of the end-to-end latency distribution
    per strategy — keys match :meth:`ScenarioPrediction.totals`.

    ``method="euler"`` inverts the Pollaczek-Khinchine transform numerically
    (accuracy-first default); ``method="asymptote"`` uses the cheap
    dominant-singularity exponential tail that the jitted fleet/cluster paths
    vectorise. Unstable strategies report ``inf``, like the mean forms.
    """
    out = {"on_device": sojourn_quantile(tail_stations(scn, "on_device"), q,
                                         method=method)}
    for i in range(len(scn.edges)):
        out[f"edge[{i}]"] = sojourn_quantile(tail_stations(scn, f"edge[{i}]"), q,
                                             method=method)
    return out


# ---------------------------------------------------------------------------
# simulate(scn): the same spec through the discrete-event testbed
# ---------------------------------------------------------------------------


def _service_dist(tier: Tier) -> S.ServiceDist:
    if tier.service_model is ServiceModel.DETERMINISTIC:
        return S.Deterministic(tier.service_time_s)
    if tier.service_model is ServiceModel.EXPONENTIAL:
        return S.Exponential(tier.service_time_s)
    return S.LogNormal(tier.service_time_s, tier.service_var)


def _tenant_dist(t: TenantStream) -> S.ServiceDist:
    return (
        S.Deterministic(t.service_mean_s)
        if t.service_var == 0
        else S.LogNormal(t.service_mean_s, t.service_var)
    )


def parse_strategy(strategy: str, n_edges: int | None = None) -> int:
    """THE parser for strategy labels: -1 for ``"on_device"``, j for
    ``"edge[j]"`` (range-checked when ``n_edges`` is given). Every consumer
    of ``Decision.target_name``-style labels — the scalar simulator, the
    validation corpus/differential harness — goes through here, so a
    malformed label always fails the same way: a ScenarioError naming the
    ``strategy`` field."""
    if strategy == "on_device":
        return -1
    m = re.fullmatch(r"edge\[(\d+)\]", strategy) if isinstance(strategy, str) else None
    if m is not None:
        j = int(m.group(1))
        if n_edges is None or j < n_edges:
            return j
    known = ["on_device"] + (
        ["edge[j]"] if n_edges is None else [f"edge[{i}]" for i in range(n_edges)])
    raise ScenarioError("strategy", f"unknown strategy {strategy!r} (known: {known})")


def _resolve_strategy(scn: Scenario, strategy: str | None) -> tuple[str, int]:
    if strategy is None:
        strategy = "edge[0]" if scn.edges else "on_device"
    return strategy, parse_strategy(strategy, len(scn.edges))


def _integer_k(tier: Tier, field_path: str) -> int:
    """The simulator runs k discrete servers; the closed forms fold k into
    k*mu and allow fractional k (§3.5). Refuse to silently simulate a
    different system than the one being predicted."""
    k = tier.parallelism_k
    if round(k) != k:
        raise ScenarioError(
            f"{field_path}.parallelism_k",
            f"fractional parallelism {k!r} cannot be simulated exactly "
            "(discrete servers); round it or compare via analytic() only",
        )
    return max(1, int(k))


def simulate(
    scn: Scenario,
    strategy: str | None = None,
    *,
    seed: int = 0,
    n: int = 100_000,
) -> S.SimResult:
    """Discrete-event simulation of ``scn`` under ``strategy``.

    Derives the right ``ServiceDist`` from each tier's ``ServiceModel``
    (deterministic / exponential / lognormal-general) and the right network
    stages from the spec, so prediction and validation can never drift apart
    on inputs (fractional ``parallelism_k`` is refused rather than silently
    rounded). ``strategy`` defaults to ``"edge[0]"`` when edges exist, else
    ``"on_device"``; multi-tenant edges use the shared-station simulator with
    the scenario's own stream observed.
    """
    strategy, idx = _resolve_strategy(scn, strategy)
    wl = scn.workload
    if strategy == "on_device":
        return S.simulate_on_device(
            wl.arrival_rate,
            _service_dist(scn.device),
            k=_integer_k(scn.device, "device"),
            n=n,
            seed=seed,
        )
    e = scn.edges[idx]
    net = scn.network_for(e)
    b = float(np.asarray(net.bandwidth_Bps))
    k_edge = _integer_k(e.tier, f"edges[{idx}].tier")
    if not e.background:
        return S.simulate_offload(
            wl.arrival_rate,
            _service_dist(e.tier),
            k_edge,
            bandwidth_Bps=b,
            req_bytes=wl.req_bytes,
            res_bytes=wl.res_bytes if scn.return_results else 0.0,
            n=n,
            seed=seed,
        )
    streams = [(wl.arrival_rate, _service_dist(e.tier))] + [
        (t.arrival_rate, _tenant_dist(t)) for t in e.background
    ]
    # rate-proportional counts -> every stream spans the same time horizon,
    # so the observed stream never sees a partially-drained edge
    lam_total = sum(rate for rate, _ in streams)
    horizon = max(n, 2_000 * len(streams)) / lam_total
    counts = [max(1, int(round(rate * horizon))) for rate, _ in streams]
    return S.simulate_multitenant_offload(
        streams,
        k_edge,
        bandwidth_Bps=b,
        req_bytes=wl.req_bytes,
        res_bytes=wl.res_bytes if scn.return_results else 0.0,
        observe_stream=0,
        n_per_stream=counts,
        seed=seed,
    )


# ---------------------------------------------------------------------------
# crossovers(scn, axis): quantitative crossover queries
# ---------------------------------------------------------------------------


def crossovers(
    scn: Scenario,
    axis: str,
    *,
    edge: int = 0,
    quantile: float | None = None,
    tail_method: str = "euler",
    **kwargs,
) -> Crossover:
    """Where does the preferred strategy flip along ``axis``?

    ``axis``: ``"bandwidth"`` (Fig. 4), ``"arrival_rate"`` (Fig. 5b; first
    crossover — they need not be unique), or ``"tenancy"`` (Fig. 5c; value is
    the smallest tenant count m at which on-device wins). Replaces the
    hand-rolled closures callers used to feed :mod:`crossover` — the solvers
    there stay the kernel layer. Edges with background tenants are compared
    via the multi-tenant (M/G/1) latency, so the answer always agrees with
    ``analytic`` on the same spec.

    ``quantile`` switches the comparison from expected latencies to the
    q-quantile of the full sojourn distributions (:mod:`repro.core.tail`) —
    the SLO view. Percentile crossovers are a result class the paper's mean
    forms cannot express: because offload paths stack three queues, their
    tails are heavier than the single on-device queue's, so p99 crossovers
    systematically shift toward on-device relative to mean crossovers.
    """
    _require(bool(scn.edges), "edges", "crossover queries need at least one edge")
    _require(0 <= edge < len(scn.edges), "edges", f"edge index {edge} out of range")
    e = scn.edges[edge]
    wl, dev = scn.workload, scn.device

    def multitenant_diff(wl_at: Workload, net: NetworkPath) -> float:
        streams = (e.own_stream(wl_at),) + e.background
        te = float(np.asarray(multitenant_edge_latency(
            wl_at, e.tier, net, streams, return_results=scn.return_results)))
        return te - float(np.asarray(on_device_latency(wl_at, dev)))

    def first_on_device_wins(te_of_m, td: float, template: TenantStream,
                             max_tenants: int) -> int | None:
        """Smallest m (own stream + (m-1) template copies) with te(m) > td.

        Homogeneous templates — service moments equal to the own stream's,
        the paper's §4.8 setup and the default — make te(m) monotone in m
        (fixed mixture moments, growing load), so the search is
        ``smallest_true``'s exponential bracket + integer bisection. A
        heterogeneous template can dip first (the mixture mean shifts toward
        the template), so it keeps the exhaustive first-hit scan.
        """
        own = e.own_stream(wl)
        homogeneous = (template.service_mean_s == own.service_mean_s
                       and template.service_var == own.service_var)
        if homogeneous:
            return smallest_true(lambda m: te_of_m(m) > td, max_tenants)
        for m in range(1, max_tenants + 1):
            if te_of_m(m) > td:
                return m
        return None

    def dev_tail(wl_at: Workload) -> float:
        return sojourn_quantile((tier_station(dev, wl_at.arrival_rate),),
                                quantile, method=tail_method)

    def edge_tail(wl_at: Workload, b: float) -> float:
        """T_edge_q at the given operating point — the same stations
        ``tail_stations`` composes, with workload/bandwidth swapped in
        (bandwidth sweeps override any per-edge path, exactly like the mean
        solvers)."""
        if e.background:
            agg = aggregate_streams((e.own_stream(wl_at),) + e.background)
            proc = mixture_station(agg.arrival_rate, agg.service_mean_s,
                                   agg.service_var, e.tier.parallelism_k)
        else:
            proc = tier_station(e.tier, wl_at.arrival_rate)
        return sojourn_quantile(
            offload_stations(wl_at.arrival_rate, wl_at.req_bytes,
                             wl_at.res_bytes, b, proc,
                             return_results=scn.return_results),
            quantile, method=tail_method)

    if quantile is not None:
        net = scn.network_for(e)
        b0 = float(np.asarray(net.bandwidth_Bps))
        if axis == "bandwidth":
            lo = kwargs.pop("lo_Bps", 1e4)
            hi = kwargs.pop("hi_Bps", 1e9)
            td_fixed = dev_tail(wl)  # bandwidth-independent: one inversion

            def diff_b(b: float) -> float:
                return edge_tail(wl, b) - td_fixed

            return solve_crossover(diff_b, lo, hi, **kwargs)
        if axis == "arrival_rate":
            lo = kwargs.pop("lo", 0.01)
            caps = [dev.parallelism_k / dev.service_time_s, b0 / wl.req_bytes]
            if not e.background:
                caps.append(e.tier.parallelism_k / e.tier.service_time_s)
                if scn.return_results and wl.res_bytes > 0:
                    caps.append(b0 / wl.res_bytes)
            hi = kwargs.pop("hi", None) or 0.999 * min(caps)
            if hi <= lo:
                return Crossover(None, None, lo, hi)

            def diff_lam(lam: float) -> float:
                wl_at = replace(wl, arrival_rate=lam)
                return edge_tail(wl_at, b0) - dev_tail(wl_at)

            return solve_crossover(diff_lam, lo, hi, **kwargs)
        if axis == "tenancy":
            max_tenants = kwargs.pop("max_tenants", 1024)
            template = kwargs.pop("tenant_template", None) or (
                e.background[0] if e.background else e.own_stream(wl)
            )
            if kwargs:
                raise TypeError(
                    f"unexpected keyword arguments for tenancy axis: {sorted(kwargs)}"
                )
            td = sojourn_quantile(tail_stations(scn, "on_device"), quantile,
                                  method=tail_method)

            def te_quantile(m: int) -> float:
                agg = aggregate_streams(
                    (e.own_stream(wl),) + (template,) * (m - 1))
                proc = mixture_station(agg.arrival_rate, agg.service_mean_s,
                                       agg.service_var, e.tier.parallelism_k)
                return sojourn_quantile(
                    offload_stations(wl.arrival_rate, wl.req_bytes,
                                     wl.res_bytes, b0, proc,
                                     return_results=scn.return_results),
                    quantile, method=tail_method)

            m_star = first_on_device_wins(te_quantile, td, template, max_tenants)
            return Crossover(
                value=None if m_star is None else float(m_star),
                offload_wins_above=None if m_star is None else False,
                lo=1.0, hi=float(max_tenants),
            )
        raise ScenarioError(
            "axis", f"unknown axis {axis!r} (known: bandwidth, arrival_rate, tenancy)"
        )

    if axis == "bandwidth":
        if e.background:
            lo = kwargs.pop("lo_Bps", 1e4)
            hi = kwargs.pop("hi_Bps", 1e9)
            return solve_crossover(
                lambda b: multitenant_diff(wl, NetworkPath(b)), lo, hi, **kwargs
            )
        return bandwidth_crossover(
            wl, dev, e.tier, return_results=scn.return_results, **kwargs
        )
    if axis == "arrival_rate":
        if e.background:
            net = scn.network_for(e)
            b = float(np.asarray(net.bandwidth_Bps))
            lo = kwargs.pop("lo", 0.01)
            # stay inside the device/NIC stability region; edge saturation
            # shows up as inf and is filtered by the solver's finite scan
            caps = [dev.parallelism_k / dev.service_time_s, b / wl.req_bytes]
            hi = kwargs.pop("hi", None) or 0.999 * min(caps)
            if hi <= lo:
                return Crossover(None, None, lo, hi)
            return solve_crossover(
                lambda lam: multitenant_diff(replace(wl, arrival_rate=lam), net),
                lo, hi, **kwargs,
            )
        xs = arrival_rate_crossovers(
            wl, dev, e.tier, scn.network_for(e),
            return_results=scn.return_results, **kwargs
        )
        return xs[0] if xs else Crossover(None, None, 0.0, 0.0)
    if axis == "tenancy":
        max_tenants = kwargs.pop("max_tenants", 1024)
        template = kwargs.pop("tenant_template", None) or (
            e.background[0] if e.background else e.own_stream(wl)
        )
        if kwargs:
            raise TypeError(
                f"unexpected keyword arguments for tenancy axis: {sorted(kwargs)}"
            )
        # m counts ALL tenants on the edge including the scenario's own
        # stream: T_edge(m) = own + (m-1) template copies. In the paper's
        # homogeneous setup (no background, template == own stream) this is
        # exactly tenancy_crossover's [template]*m; unlike that kernel form
        # it never drops the own stream when a template is supplied, so the
        # answer agrees with analytic() on the corresponding spec.
        net = scn.network_for(e)
        td = float(np.asarray(on_device_latency(wl, dev)))

        def te_mean(m: int) -> float:
            streams = (e.own_stream(wl),) + (template,) * (m - 1)
            return float(np.asarray(multitenant_edge_latency(
                wl, e.tier, net, streams, return_results=scn.return_results)))

        m_star = first_on_device_wins(te_mean, td, template, max_tenants)
        return Crossover(
            value=None if m_star is None else float(m_star),
            offload_wins_above=None if m_star is None else False,
            lo=1.0,
            hi=float(max_tenants),
        )
    raise ScenarioError(
        "axis", f"unknown axis {axis!r} (known: bandwidth, arrival_rate, tenancy)"
    )
