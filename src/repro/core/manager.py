"""Model-driven adaptive offloading manager — paper Algorithm 1 (§5.1).

Runs on the device. Each epoch it takes a telemetry snapshot (lambda, B,
per-edge load), evaluates the closed-form latency of every strategy —
on-device (Eq. 2, M/D/1) and offload-to-E for each edge server E (Eq. 1 with
M/G/1 edge processing) — and executes with the argmin. Line numbers in
comments refer to Algorithm 1 in the paper.

Beyond-paper (flag-gated, default off, recorded in EXPERIMENTS.md):
  * hysteresis — require a relative improvement before switching strategy, to
    damp flapping around a crossover;
  * SLO-quantile decisions — when ``slo_quantile`` is set, every strategy is
    scored by the q-quantile of its closed-form sojourn *distribution*
    (:mod:`repro.core.tail`) instead of its mean, so the argmin optimises the
    latency SLO directly (p95/p99) rather than a mean proxy;
  * ``tail_z`` — the DEPRECATED predecessor of the quantile mode: inflate
    both waits by ``(1 + z)`` as a crude variability penalty. Kept as a
    fallback; prefer ``slo_quantile``.
"""

from __future__ import annotations

import warnings
from dataclasses import dataclass, field
from typing import Mapping, Sequence

import numpy as np

from .latency import (
    NetworkPath,
    ServiceModel,
    Tier,
    Workload,
    mg1_wait,
    mm1_wait,
    proc_wait,
)
from .tail import (
    KIND_DET,
    KIND_EXP,
    KIND_GAMMA,
    Station,
    offload_stations,
    proc_station,
    sojourn_quantile,
)
from .telemetry import TelemetrySnapshot

__all__ = ["EdgeServerState", "Decision", "AdaptiveOffloadManager", "apply_decision_rule"]

ON_DEVICE = -1  # sentinel edge index for local execution


def apply_decision_rule(
    t_dev: float,
    t_edges: Sequence[float],
    *,
    last_index: int | None = None,
    hysteresis: float = 0.0,
) -> tuple[int, float]:
    """Algorithm 1 lines 7-11 (+ the hysteresis extension) as a pure function.

    Given the per-strategy latency predictions, returns ``(choice,
    predicted)`` where ``choice`` is ``ON_DEVICE`` or an edge index.
    On-device wins exact ties (line 7's ``<=``), matching
    ``FleetPrediction.best_edge``'s first-argmin convention. This is THE
    selection rule: ``AdaptiveOffloadManager.decide`` calls it per epoch and
    ``repro.fleet.cluster`` is its (N,)-array transcription — a coherence
    test pins the two together so the scalar and vectorized decision paths
    cannot drift apart.
    """
    if t_edges and np.isfinite(min(t_edges)):
        best_edge = int(np.argmin(t_edges))
        best_edge_t = float(t_edges[best_edge])
    else:
        best_edge, best_edge_t = ON_DEVICE, np.inf

    if t_dev <= best_edge_t:  # line 7
        choice, predicted = ON_DEVICE, t_dev  # line 8
    else:
        choice, predicted = best_edge, best_edge_t  # lines 10-11

    # beyond-paper hysteresis: keep the previous target unless the new one
    # improves by more than `hysteresis` relative.
    if hysteresis > 0.0 and last_index is not None and choice != last_index:
        prev_t = (
            t_dev
            if last_index == ON_DEVICE
            else (t_edges[last_index] if last_index < len(t_edges) else np.inf)
        )
        if np.isfinite(prev_t) and predicted > (1.0 - hysteresis) * prev_t:
            choice, predicted = last_index, float(prev_t)
    return choice, float(predicted)


@dataclass(frozen=True)
class EdgeServerState:
    """One edge server E as the manager sees it this epoch."""

    name: str
    service_rate: float  # mu_edge,E^proc — aggregated service rate (Alg. 1 input)
    arrival_rate: float  # lambda_edge,E — aggregate load (Alg. 1 input)
    service_time_s: float  # s_edge^proc for THIS workload on E
    service_var: float = 0.0  # Var[s] of E's aggregate mixture (M/G/1 term)
    parallelism_k: float = 1.0
    bandwidth_Bps: float | None = None  # per-edge path override (else device B)


@dataclass(frozen=True)
class Decision:
    strategy: str  # "on_device" | "offload"
    edge_index: int  # ON_DEVICE or index into the edges list
    predicted_latency_s: float
    t_dev: float
    t_edges: tuple[float, ...]
    epoch: int

    @property
    def target_name(self) -> str:
        return "on_device" if self.edge_index == ON_DEVICE else f"edge[{self.edge_index}]"


class AdaptiveOffloadManager:
    """Algorithm 1, plus optional hysteresis / tail-awareness extensions."""

    _MODEL_KINDS = {
        ServiceModel.DETERMINISTIC: KIND_DET,
        ServiceModel.EXPONENTIAL: KIND_EXP,
        ServiceModel.GENERAL: KIND_GAMMA,
    }

    def __init__(
        self,
        device: Tier,
        *,
        hysteresis: float = 0.0,
        tail_z: float = 0.0,
        slo_quantile: float | None = None,
        tail_method: str = "euler",
        return_results: bool = True,
        auditor=None,
        tracer=None,
        audit_source: str = "manager",
    ):
        if hysteresis < 0:
            raise ValueError("hysteresis must be >= 0")
        if slo_quantile is not None and not 0.0 < slo_quantile < 1.0:
            raise ValueError(f"slo_quantile must be in (0, 1), got {slo_quantile}")
        if tail_method not in ("euler", "asymptote"):
            raise ValueError(f"unknown tail_method {tail_method!r}")
        if tail_z > 0.0:
            if slo_quantile is not None:
                raise ValueError("tail_z and slo_quantile are mutually exclusive; "
                                 "use slo_quantile")
            warnings.warn(
                "tail_z is deprecated: it inflates the mean by a fixed factor "
                "instead of optimising a quantile; use slo_quantile=0.99 (the "
                "principled SLO mode backed by repro.core.tail)",
                DeprecationWarning, stacklevel=2,
            )
        self.device = device
        self.hysteresis = hysteresis
        self.tail_z = tail_z
        self.slo_quantile = slo_quantile
        self.tail_method = tail_method
        # paper §3.3: results consumed at the edge omit the return network
        # delay — must match the Scenario/analytic() setting or the argmin
        # disagrees with the closed forms on the same spec
        self.return_results = return_results
        # observability (repro.obs) — both duck-typed so core never imports
        # obs: `auditor` needs .record(**row), `tracer` needs .instant(...).
        # None keeps the decision path allocation-free.
        self.auditor = auditor
        self.tracer = tracer
        self.audit_source = audit_source
        self._epoch = 0
        self._last: Decision | None = None
        self.history: list[Decision] = []

    # -- Algorithm 1 lines 1-2 ------------------------------------------------
    def _device_station(self, lam_dev: float) -> Station:
        d = self.device
        return proc_station(lam_dev, self._MODEL_KINDS[d.service_model],
                            d.service_time_s, d.service_var, d.parallelism_k)

    def _device_terms(self, lam_dev: float) -> dict[str, float]:
        """The mean on-device decomposition, keyed and ordered exactly like
        ``on_device_latency(..., breakdown=True)`` — the audit layer's term
        re-sum invariant holds by construction because ``_predict_device``
        derives its mean prediction from this very dict."""
        # proc_wait dispatches on the device's service model (M/D/1, M/M/1,
        # or M/G/1 with its variance) exactly as the paper's lines 1-2 do —
        # duplicating that dispatch here is how GENERAL was once mis-modeled
        w = float(proc_wait(self.device, lam_dev))
        if self.tail_z > 0.0:
            # deprecated fallback — the SAME variability inflation the edge
            # path gets, so equal-variability specs are treated symmetrically
            w = w * (1.0 + self.tail_z)
        return {"w_proc_dev": w, "s_dev": self.device.service_time_s}

    def _predict_device(self, lam_dev: float) -> float:
        if self.slo_quantile is not None:
            return float(sojourn_quantile((self._device_station(lam_dev),),
                                          self.slo_quantile, method=self.tail_method))
        t = self._device_terms(lam_dev)
        return t["w_proc_dev"] + t["s_dev"]

    # -- Algorithm 1 lines 3-6 ------------------------------------------------
    @staticmethod
    def _edge_bandwidth(edge: EdgeServerState, bandwidth_Bps: float) -> float | None:
        """Resolve the path bandwidth for this edge (per-edge override wins);
        None means the link is dead/saturated this epoch."""
        if edge.bandwidth_Bps is not None and edge.bandwidth_Bps <= 0:
            # an explicit per-edge override of 0.0 is a config error, not "unset"
            raise ValueError(
                f"edge {edge.name!r}: bandwidth override must be positive, "
                f"got {edge.bandwidth_Bps!r}"
            )
        b = bandwidth_Bps if edge.bandwidth_Bps is None else edge.bandwidth_Bps
        if b is None or b <= 0:
            # measured bandwidth can hit 0 during an outage: the link is
            # saturated/dead, so offloading is never preferable this epoch
            return None
        return b

    def _edge_terms(
        self, edge: EdgeServerState, wl: Workload, lam_dev: float, bandwidth_Bps: float
    ) -> dict[str, float]:
        """The mean offload decomposition — the same six terms, keys, and
        order as ``edge_offload_latency(..., breakdown=True)`` (Eq. 1 /
        Alg. 1 lines 3-6). ``_predict_edge`` sums this dict in mean mode."""
        b = self._edge_bandwidth(edge, bandwidth_Bps)
        if b is None:
            return {"w_net_dev": float(np.inf), "n_req": 0.0,
                    "w_proc_edge": 0.0, "s_edge": edge.service_time_s,
                    "w_net_edge": 0.0, "n_res": 0.0}
        # zero-byte payloads mean "no transfer on this leg" (e.g. results
        # consumed at the edge) — the NIC queue degenerates to zero delay
        if wl.req_bytes > 0:
            # line 3: T_net_req <- M/M/1(lambda_dev, B/D_req) + D_req/B
            w_net_dev = float(mm1_wait(lam_dev, b / wl.req_bytes))
            n_req = wl.req_bytes / b
        else:
            w_net_dev = n_req = 0.0
        if self.return_results and wl.res_bytes > 0:
            # line 4: T_net_res <- M/M/1(lambda_edge,E, B/D_res) + D_res/B
            w_net_edge = float(mm1_wait(edge.arrival_rate, b / wl.res_bytes))
            n_res = wl.res_bytes / b
        else:
            w_net_edge = n_res = 0.0
        # line 6: M/G/1 wait on the edge's aggregate mixture
        w_proc = float(
            mg1_wait(edge.arrival_rate, edge.service_rate, edge.service_var, edge.parallelism_k)
        )
        if self.tail_z > 0.0:
            # beyond-paper: penalise variability when an SLO is set.
            # sigma_w proxy: for M/G/1 the wait is roughly exponential-tailed
            # with scale E[w]; mean + z*E[w] is a cheap upper quantile proxy.
            w_proc = w_proc * (1.0 + self.tail_z)
        return {"w_net_dev": w_net_dev, "n_req": n_req,
                "w_proc_edge": w_proc, "s_edge": edge.service_time_s,
                "w_net_edge": w_net_edge, "n_res": n_res}

    def _predict_edge(
        self, edge: EdgeServerState, wl: Workload, lam_dev: float, bandwidth_Bps: float
    ) -> float:
        b = self._edge_bandwidth(edge, bandwidth_Bps)
        if b is None:
            return float(np.inf)
        if self.slo_quantile is not None:
            # SLO mode: score the q-quantile of the composed sojourn
            # distribution over the same three stations lines 3-6 price by
            # their means. The edge wait is the aggregate-mixture M/G/1
            # (gamma-matched), line 6's own service time rides on top.
            k_mu = edge.parallelism_k * edge.service_rate
            proc = Station(edge.arrival_rate, KIND_GAMMA, 1.0 / k_mu,
                           edge.service_var, KIND_GAMMA, edge.service_time_s,
                           edge.service_var)
            stations = offload_stations(lam_dev, wl.req_bytes, wl.res_bytes, b,
                                        proc, return_results=self.return_results)
            return float(sojourn_quantile(stations, self.slo_quantile,
                                          method=self.tail_method))
        # line 6: T_edge,E <- T_req + M/G/1(lambda_E, mu_E) + s_edge + T_res —
        # summed in LatencyBreakdown's key order so the prediction IS the sum
        # of its own audit terms (bit-exact, not just within tolerance)
        t = self._edge_terms(edge, wl, lam_dev, bandwidth_Bps)
        return (t["w_net_dev"] + t["n_req"] + t["w_proc_edge"]
                + t["s_edge"] + t["w_net_edge"] + t["n_res"])

    # -- Algorithm 1 lines 7-11 -----------------------------------------------
    def decide(
        self,
        wl: Workload,
        snapshot: TelemetrySnapshot,
        edges: Sequence[EdgeServerState],
    ) -> Decision:
        lam_dev = snapshot.lam_dev
        last_index = None if self._last is None else self._last.edge_index
        t_dev = self._predict_device(lam_dev)
        t_edges = tuple(
            self._predict_edge(e, wl, lam_dev, snapshot.bandwidth_Bps) for e in edges
        )
        choice, predicted = apply_decision_rule(
            t_dev,
            t_edges,
            last_index=last_index,
            hysteresis=self.hysteresis,
        )

        decision = Decision(
            strategy="on_device" if choice == ON_DEVICE else "offload",
            edge_index=choice,
            predicted_latency_s=float(predicted),
            t_dev=t_dev,
            t_edges=t_edges,
            epoch=self._epoch,
        )
        if self.auditor is not None:
            self._audit(decision, wl, snapshot, edges, last_index)
        if self.tracer is not None:
            self.tracer.instant(
                t=snapshot.time_s, name="decide", cat="decide",
                track=self.audit_source, epoch=decision.epoch,
                target=decision.target_name,
                predicted_latency_s=decision.predicted_latency_s,
            )
        self._epoch += 1
        self._last = decision
        self.history.append(decision)
        return decision

    def _audit(self, decision, wl, snapshot, edges, last_index) -> None:
        """Record the full closed-form story behind ``decision`` (repro.obs).

        In mean mode the audited totals ARE the ordered sums of the audited
        terms (the predictions are computed that way); in SLO-quantile mode
        the totals are q-quantiles, so the mean decomposition is logged
        alongside under ``term_totals`` and ``decision_metric`` says which
        metric the argmin ranked.
        """
        terms: dict[str, dict[str, float]] = {
            "on_device": self._device_terms(snapshot.lam_dev)}
        for i, e in enumerate(edges):
            terms[f"edge[{i}]"] = self._edge_terms(
                e, wl, snapshot.lam_dev, snapshot.bandwidth_Bps)
        term_totals = {
            "on_device": terms["on_device"]["w_proc_dev"] + terms["on_device"]["s_dev"]}
        for i in range(len(edges)):
            t = terms[f"edge[{i}]"]
            term_totals[f"edge[{i}]"] = (
                t["w_net_dev"] + t["n_req"] + t["w_proc_edge"]
                + t["s_edge"] + t["w_net_edge"] + t["n_res"])
        totals = {"on_device": decision.t_dev}
        for i, v in enumerate(decision.t_edges):
            totals[f"edge[{i}]"] = v
        alts = [v for k, v in totals.items() if k != decision.target_name]
        chosen_total = totals[decision.target_name]
        margin = min(alts) - chosen_total if alts else float(np.inf)
        if np.isnan(margin):  # inf - inf: everything saturated, no margin story
            margin = 0.0
        # hysteresis engaged <=> the no-hysteresis rule picks differently
        raw_choice, _ = apply_decision_rule(decision.t_dev, decision.t_edges)
        self.auditor.record(
            epoch=decision.epoch,
            time_s=snapshot.time_s,
            source=self.audit_source,
            chosen=decision.target_name,
            edge_index=decision.edge_index,
            predicted_latency_s=decision.predicted_latency_s,
            decision_metric=("mean" if self.slo_quantile is None
                             else f"p{self.slo_quantile * 100:g}"),
            totals=totals,
            terms=terms,
            term_totals=term_totals,
            snapshot={
                "time_s": snapshot.time_s,
                "lam_dev": snapshot.lam_dev,
                "bandwidth_Bps": snapshot.bandwidth_Bps,
                "edge_arrival_rates": [e.arrival_rate for e in edges],
                "edge_service_rates": [e.service_rate for e in edges],
                "edge_service_vars": [e.service_var for e in edges],
            },
            margin_s=float(margin),
            hysteresis={
                "hysteresis": self.hysteresis,
                "last_index": last_index,
                "engaged": raw_choice != decision.edge_index,
            },
            slo_quantile=self.slo_quantile,
        )

    # -- shared epoch entry point ----------------------------------------------
    def step(self, t: float, metrics: Mapping) -> Decision:
        """One epoch from measured metrics — the single decision path shared
        by the serving gateway and the fleet trace replay.

        ``metrics`` keys: ``workload`` (:class:`Workload`), ``lam_dev`` and
        ``bandwidth_Bps`` (estimator outputs, *not* raw instantaneous values),
        and optionally ``edges`` (a sequence of :class:`EdgeServerState`).
        Builds the :class:`TelemetrySnapshot` and runs Algorithm 1 lines 1-11;
        keeping snapshot assembly here means no consumer re-implements the
        dispatch and the two paths can never disagree on the same metrics.
        """
        for key in ("workload", "lam_dev", "bandwidth_Bps"):
            if key not in metrics:
                raise KeyError(f"metrics missing required key {key!r}")
        snap = TelemetrySnapshot(
            time_s=t,
            lam_dev=float(metrics["lam_dev"]),
            bandwidth_Bps=float(metrics["bandwidth_Bps"]),
        )
        return self.decide(metrics["workload"], snap, tuple(metrics.get("edges", ())))

    @property
    def switches(self) -> int:
        """Number of strategy changes so far (flapping metric)."""
        return sum(
            1
            for a, b in zip(self.history, self.history[1:])
            if a.edge_index != b.edge_index
        )
