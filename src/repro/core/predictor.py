"""Learned service-time predictor (paper §3.2, after Neurosurgeon [22]).

"For model-based prediction, a neural network can be trained to predict the
service time of a model on a given hardware ... in our experiments we adopt a
simple neural network from [22]."

A small JAX MLP maps workload features (log-FLOPs, log-params, log-payload,
batch, sequence length, ...) to log service time. Trained with Adam +
standardised features; used by the split planner to avoid profiling every
split configuration (paper §4.2) and by the gateway when no profile exists.
"""

from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

__all__ = ["LatencyPredictor", "workload_features"]


def workload_features(
    flops: float, param_bytes: float, act_bytes: float, batch: int, seq: int
) -> np.ndarray:
    """Canonical feature vector; logs tame the dynamic range (1e6..1e15)."""
    return np.array(
        [
            np.log10(max(flops, 1.0)),
            np.log10(max(param_bytes, 1.0)),
            np.log10(max(act_bytes, 1.0)),
            np.log10(max(batch, 1)),
            np.log10(max(seq, 1)),
        ],
        dtype=np.float32,
    )


def _init_mlp(key, sizes: Sequence[int]):
    params = []
    for i, (fan_in, fan_out) in enumerate(zip(sizes[:-1], sizes[1:])):
        key, sub = jax.random.split(key)
        w = jax.random.normal(sub, (fan_in, fan_out)) * jnp.sqrt(2.0 / fan_in)
        params.append({"w": w.astype(jnp.float32), "b": jnp.zeros((fan_out,), jnp.float32)})
    return params


def _apply_mlp(params, x):
    for i, layer in enumerate(params):
        x = x @ layer["w"] + layer["b"]
        if i < len(params) - 1:
            x = jax.nn.gelu(x)
    return x[..., 0]


@partial(jax.jit, static_argnames=())
def _loss(params, x, y):
    pred = _apply_mlp(params, x)
    return jnp.mean((pred - y) ** 2)


@dataclass
class _AdamState:
    m: list
    v: list
    step: int


class LatencyPredictor:
    """MLP: standardized features -> log10(service seconds)."""

    def __init__(self, n_features: int = 5, hidden: Sequence[int] = (64, 64), seed: int = 0):
        self.sizes = [n_features, *hidden, 1]
        self.params = _init_mlp(jax.random.PRNGKey(seed), self.sizes)
        self._mu = np.zeros(n_features, np.float32)
        self._sigma = np.ones(n_features, np.float32)
        self._fitted = False

    # ------------------------------------------------------------------
    def fit(
        self,
        features: np.ndarray,
        latencies_s: np.ndarray,
        *,
        steps: int = 2000,
        lr: float = 1e-3,
        batch_size: int = 256,
        seed: int = 0,
    ) -> float:
        """Train on (N, F) features vs (N,) latencies. Returns final MSE (log-space)."""
        x = np.asarray(features, np.float32)
        y = np.log10(np.maximum(np.asarray(latencies_s, np.float32), 1e-9))
        if x.ndim != 2 or x.shape[0] != y.shape[0]:
            raise ValueError("features must be (N,F) matching latencies (N,)")
        self._mu = x.mean(axis=0)
        self._sigma = x.std(axis=0) + 1e-6
        xn = (x - self._mu) / self._sigma

        grad_fn = jax.jit(jax.value_and_grad(_loss))
        m = jax.tree.map(jnp.zeros_like, self.params)
        v = jax.tree.map(jnp.zeros_like, self.params)
        b1, b2, eps = 0.9, 0.999, 1e-8
        rng = np.random.default_rng(seed)
        params = self.params
        loss_val = np.inf
        n = xn.shape[0]
        for t in range(1, steps + 1):
            idx = rng.integers(0, n, size=min(batch_size, n))
            loss_val, grads = grad_fn(params, jnp.asarray(xn[idx]), jnp.asarray(y[idx]))
            m = jax.tree.map(lambda a, g: b1 * a + (1 - b1) * g, m, grads)
            v = jax.tree.map(lambda a, g: b2 * a + (1 - b2) * g * g, v, grads)
            mh = jax.tree.map(lambda a: a / (1 - b1**t), m)
            vh = jax.tree.map(lambda a: a / (1 - b2**t), v)
            params = jax.tree.map(
                lambda p, a, b: p - lr * a / (jnp.sqrt(b) + eps), params, mh, vh
            )
        self.params = params
        self._fitted = True
        return float(loss_val)

    # ------------------------------------------------------------------
    def predict(self, features: np.ndarray) -> np.ndarray:
        """Predicted service seconds for (N, F) or (F,) features."""
        if not self._fitted:
            raise RuntimeError("predictor not fitted")
        x = np.atleast_2d(np.asarray(features, np.float32))
        xn = (x - self._mu) / self._sigma
        logs = np.asarray(_apply_mlp(self.params, jnp.asarray(xn)))
        out = 10.0**logs
        return out if out.shape[0] > 1 else out[0]

    def mape(self, features: np.ndarray, latencies_s: np.ndarray) -> float:
        pred = np.atleast_1d(self.predict(features))
        obs = np.asarray(latencies_s, np.float64)
        return float(np.mean(np.abs(pred - obs) / obs) * 100.0)
