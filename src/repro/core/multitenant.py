"""Multi-tenant edge modelling (paper §3.4).

An edge server multiplexed across m devices sees the superposition of m
independent Poisson streams — itself Poisson with lambda_edge = sum_i lambda_i
— and an *arbitrary mixture* service distribution, hence M/G/1 (Lemma 3.2).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .latency import NetworkPath, ServiceModel, Tier, Workload, edge_offload_latency

__all__ = ["TenantStream", "AggregateLoad", "aggregate_streams", "multitenant_edge_latency"]


@dataclass(frozen=True)
class TenantStream:
    """One co-located application's offloaded stream as seen by the edge."""

    arrival_rate: float  # lambda_i
    service_mean_s: float  # s_i (service time of THIS app's requests at the edge)
    service_var: float = 0.0  # within-app service variance
    name: str = "tenant"


@dataclass(frozen=True)
class AggregateLoad:
    """The edge's effective M/G/1 inputs under multiplexing."""

    arrival_rate: float  # lambda_edge
    service_mean_s: float  # s_edge = sum_i (lambda_i/lambda_edge) s_i
    service_var: float  # Var[s_edge] of the mixture
    utilisation: float  # rho_edge = lambda_edge * s_edge

    @property
    def service_rate(self) -> float:
        return 1.0 / self.service_mean_s


def aggregate_streams(streams: Sequence[TenantStream]) -> AggregateLoad:
    """Poisson superposition + mixture moments (paper §3.4).

    lambda_edge = sum_i lambda_i                         (superposition, [43])
    s_edge      = sum_i (lambda_i / lambda_edge) s_i     (weighted mean)
    Var[s_edge] = E[s^2] - s_edge^2
                = sum_i w_i (var_i + s_i^2) - s_edge^2   (law of total variance)
    """
    if not streams:
        raise ValueError("need at least one tenant stream")
    lam_edge = float(sum(t.arrival_rate for t in streams))
    if lam_edge <= 0:
        raise ValueError("aggregate arrival rate must be positive")
    weights = np.array([t.arrival_rate / lam_edge for t in streams])
    means = np.array([t.service_mean_s for t in streams])
    variances = np.array([t.service_var for t in streams])
    s_edge = float(weights @ means)
    second_moment = float(weights @ (variances + means**2))
    var = max(0.0, second_moment - s_edge**2)
    return AggregateLoad(lam_edge, s_edge, var, lam_edge * s_edge)


def multitenant_edge_latency(
    wl: Workload,
    edge: Tier,
    net: NetworkPath,
    streams: Sequence[TenantStream],
    **kw,
):
    """End-to-end offload latency for ``wl`` when the edge also serves ``streams``.

    The edge tier is re-parameterised with the aggregate mixture service
    (mean + variance) and evaluated as M/G/1 — exactly Lemma 3.2's setting.
    ``wl``'s own stream must be included in ``streams`` by the caller.
    """
    agg = aggregate_streams(streams)
    edge_mg1 = Tier(
        name=edge.name,
        service_time_s=agg.service_mean_s,
        parallelism_k=edge.parallelism_k,
        service_model=ServiceModel.GENERAL,
        service_var=agg.service_var,
    )
    return edge_offload_latency(
        wl, edge_mg1, net, edge_arrival_rate=agg.arrival_rate, **kw
    )
