"""Multi-tenant edge modelling (paper §3.4).

An edge server multiplexed across m devices sees the superposition of m
independent Poisson streams — itself Poisson with lambda_edge = sum_i lambda_i
— and an *arbitrary mixture* service distribution, hence M/G/1 (Lemma 3.2).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Sequence

import numpy as np

from .latency import NetworkPath, ServiceModel, Tier, Workload, edge_offload_latency

__all__ = [
    "TenantStream",
    "AggregateLoad",
    "aggregate_streams",
    "mixture_moments",
    "multitenant_edge_latency",
]


@dataclass(frozen=True)
class TenantStream:
    """One co-located application's offloaded stream as seen by the edge."""

    arrival_rate: float  # lambda_i
    service_mean_s: float  # s_i (service time of THIS app's requests at the edge)
    service_var: float = 0.0  # within-app service variance
    name: str = "tenant"


@dataclass(frozen=True)
class AggregateLoad:
    """The edge's effective M/G/1 inputs under multiplexing."""

    arrival_rate: float  # lambda_edge
    service_mean_s: float  # s_edge = sum_i (lambda_i/lambda_edge) s_i
    service_var: float  # Var[s_edge] of the mixture
    utilisation: float  # rho_edge = lambda_edge * s_edge

    @property
    def service_rate(self) -> float:
        return 1.0 / self.service_mean_s


def mixture_moments(rates, means, variances):
    """Vectorized §3.4 aggregation: the mixture's (rate, mean, variance).

    Reduces over the LAST axis — for ``(..., m)`` inputs of per-stream rates,
    service means, and within-stream variances, returns ``(lam_tot, mean_mix,
    var_mix)`` with shape ``(...)``: Poisson-superposition total rate, the
    rate-weighted mean, and the law-of-total-variance mixture variance. A
    zero total rate yields ``(0, 0, 0)`` (no load, not an error) so closed
    loops with momentarily-idle edges stay finite; :func:`aggregate_streams`
    is the validated scalar form built on top of this.
    """
    rates = np.asarray(rates, dtype=np.float64)
    means = np.asarray(means, dtype=np.float64)
    variances = np.asarray(variances, dtype=np.float64)
    lam_tot = rates.sum(axis=-1)
    safe = np.where(lam_tot > 0, lam_tot, 1.0)
    mean_mix = (rates * means).sum(axis=-1) / safe
    second = (rates * (variances + means**2)).sum(axis=-1) / safe
    var_mix = np.maximum(0.0, second - mean_mix**2)
    zero = lam_tot <= 0
    return (
        lam_tot,
        np.where(zero, 0.0, mean_mix),
        np.where(zero, 0.0, var_mix),
    )


def aggregate_streams(streams: Sequence[TenantStream]) -> AggregateLoad:
    """Poisson superposition + mixture moments (paper §3.4).

    lambda_edge = sum_i lambda_i                         (superposition, [43])
    s_edge      = sum_i (lambda_i / lambda_edge) s_i     (weighted mean)
    Var[s_edge] = E[s^2] - s_edge^2
                = sum_i w_i (var_i + s_i^2) - s_edge^2   (law of total variance)
    """
    if not streams:
        raise ValueError("need at least one tenant stream")
    if sum(t.arrival_rate for t in streams) <= 0:
        raise ValueError("aggregate arrival rate must be positive")
    lam_edge, s_edge, var = mixture_moments(
        [t.arrival_rate for t in streams],
        [t.service_mean_s for t in streams],
        [t.service_var for t in streams],
    )
    return AggregateLoad(float(lam_edge), float(s_edge), float(var),
                         float(lam_edge) * float(s_edge))


def multitenant_edge_latency(
    wl: Workload,
    edge: Tier,
    net: NetworkPath,
    streams: Sequence[TenantStream],
    **kw,
):
    """End-to-end offload latency for ``wl`` when the edge also serves ``streams``.

    The edge tier is re-parameterised with the aggregate mixture service
    (mean + variance) and evaluated as M/G/1 — exactly Lemma 3.2's setting.
    ``wl``'s own stream must be included in ``streams`` by the caller.
    """
    agg = aggregate_streams(streams)
    edge_mg1 = Tier(
        name=edge.name,
        service_time_s=agg.service_mean_s,
        parallelism_k=edge.parallelism_k,
        service_model=ServiceModel.GENERAL,
        service_var=agg.service_var,
    )
    return edge_offload_latency(
        wl, edge_mg1, net, edge_arrival_rate=agg.arrival_rate, **kw
    )
