"""Hardware-in-the-loop service-time profiling (ROADMAP item 1).

Closes the paper's experimental loop: the real serving engine runs under a
Poisson workload on a deterministic simulated-or-wall clock (``harness``),
the recorded trace is fitted into per-(phase, occupancy) service-time
distributions classified into the paper's M/D/1 / M/M/1 / M/G/1 taxonomy
(``fit``), and the fits are serialized as a versioned ``MeasuredProfile``
artifact that ``Tier.from_measured`` turns into an ordinary analytic tier
(``profile``). ``repro.validate.measured`` then gates the closed forms
against the *observed* engine latencies, paper-§5 style.
"""

from .harness import (
    HarnessConfig,
    MeasuredTrace,
    RequestRecord,
    SimulatedTimer,
    run_harness,
)
from .fit import (
    DET_SCV_MAX,
    EXP_SCV_BAND,
    PERCENTILES,
    DistFit,
    classify_service_model,
    fit_samples,
    fit_trace,
)
from .profile import (
    PROFILE_VERSION,
    MeasuredProfile,
    build_profile,
    load_profile,
)

__all__ = [k for k in dir() if not k.startswith("_")]
