"""Versioned MeasuredProfile artifact: the bridge from measurement to model.

A profile bundles everything the analytic layer needs from one profiling
run: the fitted per-(phase, occupancy) distributions, the resolved arrival
rate, and the *observed* end-to-end latency statistics the validation gate
scores against. Serialization is canonical JSON (sorted keys, fixed indent,
trailing newline) so a profile round-trips byte-for-byte — profiles are
meant to be committed next to benchmark baselines.

``Tier.from_measured(profile, occupancy)`` consumes the duck-typed
:meth:`MeasuredProfile.service_moments`; nothing in ``repro.core`` imports
this package.
"""

from __future__ import annotations

import json
from dataclasses import dataclass
from pathlib import Path
from typing import Mapping

import numpy as np

from repro.core.latency import ServiceModel
from repro.validate.metrics import bootstrap_mean_ci

from .fit import DistFit, fit_trace
from .harness import MeasuredTrace

__all__ = ["PROFILE_VERSION", "MeasuredProfile", "build_profile", "load_profile"]

PROFILE_VERSION = 1


@dataclass(frozen=True)
class MeasuredProfile:
    """Fitted service-time profile of one (model config, engine setup) pair."""

    arch: str
    clock: str  # "simulated" | "wall"
    seed: int
    slots: int
    arrival_rate: float
    n_requests: int
    fits: tuple[DistFit, ...]
    observed: tuple[tuple[str, float], ...]  # end-to-end latency stats (sorted keys)
    workload: tuple[tuple[str, float], ...]  # workload shape summary (sorted keys)
    manifest: Mapping | None = None  # run provenance (repro.obs.run_manifest)
    version: int = PROFILE_VERSION

    # -- lookups -------------------------------------------------------------
    def fit_for(self, phase: str, occupancy: int) -> DistFit:
        for f in self.fits:
            if f.phase == phase and f.occupancy == occupancy:
                return f
        have = [(f.phase, f.occupancy) for f in self.fits]
        raise KeyError(f"no fit for ({phase!r}, occupancy={occupancy}); "
                       f"profiled groups: {have}")

    def occupancies(self, phase: str = "request") -> list[int]:
        return sorted(f.occupancy for f in self.fits if f.phase == phase)

    def dominant_occupancy(self, phase: str = "request") -> int:
        """The occupancy with the most samples — the default gate target."""
        cands = [f for f in self.fits if f.phase == phase]
        if not cands:
            raise KeyError(f"profile has no {phase!r} fits")
        return max(cands, key=lambda f: (f.n, -f.occupancy)).occupancy

    def service_moments(self, occupancy: int) -> tuple[float, float, ServiceModel]:
        """(mean_s, var_s, model) of the request-level service at the given
        batch occupancy — the ``Tier.from_measured`` protocol."""
        return self.fit_for("request", int(occupancy)).moments()

    def observed_stat(self, key: str) -> float:
        for k, v in self.observed:
            if k == key:
                return v
        raise KeyError(f"no observed stat {key!r} "
                       f"(have {[k for k, _ in self.observed]})")

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> dict:
        d = {
            "version": self.version,
            "arch": self.arch,
            "clock": self.clock,
            "seed": self.seed,
            "slots": self.slots,
            "arrival_rate": self.arrival_rate,
            "n_requests": self.n_requests,
            "workload": {k: v for k, v in self.workload},
            "observed": {k: v for k, v in self.observed},
            "fits": [f.to_dict() for f in self.fits],
        }
        if self.manifest is not None:
            d["manifest"] = dict(self.manifest)
        return d

    @classmethod
    def from_dict(cls, d: Mapping) -> "MeasuredProfile":
        version = int(d.get("version", 0))
        if version != PROFILE_VERSION:
            raise ValueError(
                f"unsupported MeasuredProfile version {version} "
                f"(this build reads version {PROFILE_VERSION})")
        return cls(
            arch=d["arch"],
            clock=d["clock"],
            seed=int(d["seed"]),
            slots=int(d["slots"]),
            arrival_rate=float(d["arrival_rate"]),
            n_requests=int(d["n_requests"]),
            fits=tuple(DistFit.from_dict(f) for f in d["fits"]),
            observed=tuple(sorted(
                (str(k), float(v)) for k, v in d.get("observed", {}).items())),
            workload=tuple(sorted(
                (str(k), float(v)) for k, v in d.get("workload", {}).items())),
            manifest=d.get("manifest"),
            version=version,
        )

    def dumps(self) -> str:
        """Canonical serialization — byte-stable across round-trips."""
        return json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n"

    def save(self, path: str | Path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(self.dumps())
        return path


def load_profile(path: str | Path) -> MeasuredProfile:
    return MeasuredProfile.from_dict(json.loads(Path(path).read_text()))


def build_profile(trace: MeasuredTrace, *, seed: int = 0,
                  min_group: int = 8, manifest: Mapping | None = None) -> MeasuredProfile:
    """Fit a trace and package it as a :class:`MeasuredProfile`.

    The observed block records what the engine actually delivered end to
    end (mean/percentile latency, queue wait, a block-bootstrap CI on the
    mean) — the ground truth the measured validation gate compares the
    closed forms against. ``manifest`` (``repro.obs.run_manifest``) stamps
    the run's provenance into the artifact; it is timestamp-free, so the
    profile stays byte-stable per seed.
    """
    hc = trace.harness
    lat = trace.latencies()
    waits = np.array([r.queue_wait_s for r in trace.requests])
    service = np.array([r.service_s for r in trace.requests])
    ci = bootstrap_mean_ci(lat, seed=seed)
    observed = {
        "latency_mean_s": float(lat.mean()),
        "latency_p50_s": float(np.percentile(lat, 50)),
        "latency_p90_s": float(np.percentile(lat, 90)),
        "latency_p99_s": float(np.percentile(lat, 99)),
        "latency_mean_ci_lo_s": float(ci.lo),
        "latency_mean_ci_hi_s": float(ci.hi),
        "queue_wait_mean_s": float(waits.mean()),
        "service_mean_s": float(service.mean()),
        "rho_hat": float(trace.arrival_rate * service.mean() / hc.slots),
        "n": float(lat.size),
    }
    workload = {
        "prompt_len": float(hc.prompt_len),
        "prompt_len_jitter": float(hc.prompt_len_jitter),
        "max_new_tokens": float(hc.max_new_tokens),
        "new_tokens_geometric_p": float(hc.new_tokens_geometric_p),
        "target_rho": float(hc.target_rho),
    }
    return MeasuredProfile(
        arch=hc.arch,
        clock=hc.clock,
        seed=hc.seed,
        slots=hc.slots,
        arrival_rate=float(trace.arrival_rate),
        n_requests=len(trace.requests),
        fits=tuple(fit_trace(trace, seed=seed, min_group=min_group)),
        observed=tuple(sorted(observed.items())),
        workload=tuple(sorted(workload.items())),
        manifest=manifest,
    )
