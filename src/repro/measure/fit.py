"""Fit measured service-time distributions into the paper's taxonomy.

Each (phase, batch occupancy) group of a trace becomes one :class:`DistFit`:
sample mean, variance, SCV (squared coefficient of variation), empirical
percentiles, a moving-block bootstrap CI on the mean (reusing
``validate.metrics`` — latency samples are serially correlated through the
queue), and a :class:`~repro.core.latency.ServiceModel` classification:

  SCV <= DET_SCV_MAX        -> DETERMINISTIC (M/D/1, Lemma 3.1)
  |SCV - 1| <= EXP_SCV_BAND -> EXPONENTIAL   (M/M/1, Lemma 3.3)
  otherwise                 -> GENERAL       (two-moment M/G/1, Lemma 3.2)

The GENERAL branch carries the sample variance, so downstream
Pollaczek-Khinchine forms see an exact two-moment match of the measurement.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Iterable, Mapping, Sequence

import numpy as np

from repro.core.latency import ServiceModel
from repro.validate.metrics import bootstrap_mean_ci

__all__ = [
    "DET_SCV_MAX",
    "EXP_SCV_BAND",
    "PERCENTILES",
    "classify_service_model",
    "DistFit",
    "fit_samples",
    "fit_trace",
]

DET_SCV_MAX = 0.02
EXP_SCV_BAND = 0.35
PERCENTILES = (50.0, 90.0, 95.0, 99.0)

PHASES = ("prefill", "decode", "request")


def classify_service_model(mean_s: float, var_s: float) -> ServiceModel:
    """Two-moment classification into the paper's queueing taxonomy."""
    if not mean_s > 0:
        raise ValueError(f"mean service must be > 0, got {mean_s}")
    if var_s < 0:
        raise ValueError(f"service variance must be >= 0, got {var_s}")
    scv = var_s / mean_s**2
    if scv <= DET_SCV_MAX:
        return ServiceModel.DETERMINISTIC
    if abs(scv - 1.0) <= EXP_SCV_BAND:
        return ServiceModel.EXPONENTIAL
    return ServiceModel.GENERAL


@dataclass(frozen=True)
class DistFit:
    """A fitted service-time distribution for one (phase, occupancy) group."""

    phase: str  # "prefill" | "decode" | "request"
    occupancy: int
    n: int
    mean_s: float
    var_s: float
    model: ServiceModel
    percentiles: tuple[tuple[str, float], ...]  # (("p50", ...), ...)
    ci_lo_s: float
    ci_hi_s: float
    ci_level: float

    @property
    def scv(self) -> float:
        return self.var_s / self.mean_s**2

    @property
    def ci_half_width_pct(self) -> float:
        """Mean-CI half width as % of the mean — the statistical resolution
        floor for any MAPE computed against this fit."""
        return 0.5 * (self.ci_hi_s - self.ci_lo_s) / abs(self.mean_s) * 100.0

    def percentile(self, p: float) -> float:
        key = _pkey(p)
        for k, v in self.percentiles:
            if k == key:
                return v
        raise KeyError(f"percentile {key} not fitted "
                       f"(have {[k for k, _ in self.percentiles]})")

    def moments(self) -> tuple[float, float, ServiceModel]:
        return self.mean_s, self.var_s, self.model

    def to_dict(self) -> dict:
        return {
            "phase": self.phase,
            "occupancy": self.occupancy,
            "n": self.n,
            "mean_s": self.mean_s,
            "var_s": self.var_s,
            "scv": self.scv,
            "model": self.model.value,
            "percentiles": {k: v for k, v in self.percentiles},
            "ci": {"lo_s": self.ci_lo_s, "hi_s": self.ci_hi_s,
                   "level": self.ci_level},
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "DistFit":
        ci = d.get("ci", {})
        return cls(
            phase=d["phase"],
            occupancy=int(d["occupancy"]),
            n=int(d["n"]),
            mean_s=float(d["mean_s"]),
            var_s=float(d["var_s"]),
            model=ServiceModel(d["model"]),
            percentiles=tuple(sorted(
                (str(k), float(v)) for k, v in d.get("percentiles", {}).items())),
            ci_lo_s=float(ci.get("lo_s", d["mean_s"])),
            ci_hi_s=float(ci.get("hi_s", d["mean_s"])),
            ci_level=float(ci.get("level", 0.95)),
        )


def _pkey(p: float) -> str:
    return f"p{p:g}"


def fit_samples(samples: Iterable[float], *, phase: str, occupancy: int,
                percentiles: Sequence[float] = PERCENTILES,
                seed: int = 0) -> DistFit:
    """Fit one sample group. Samples must be positive durations in seconds."""
    x = np.asarray(list(samples), dtype=np.float64)
    if x.size == 0:
        raise ValueError(f"no samples for ({phase}, occupancy={occupancy})")
    if not np.all(x > 0):
        raise ValueError(f"service samples must be positive ({phase}, "
                         f"occupancy={occupancy})")
    mean = float(x.mean())
    var = float(x.var())
    ci = bootstrap_mean_ci(x, seed=seed)
    pcts = tuple(sorted(
        (_pkey(p), float(np.percentile(x, p))) for p in percentiles))
    return DistFit(
        phase=phase,
        occupancy=int(occupancy),
        n=int(x.size),
        mean_s=mean,
        var_s=var,
        model=classify_service_model(mean, var),
        percentiles=pcts,
        ci_lo_s=float(ci.lo),
        ci_hi_s=float(ci.hi),
        ci_level=float(ci.level),
    )


def fit_trace(trace, *, seed: int = 0, min_group: int = 8) -> list[DistFit]:
    """All fits of a :class:`~repro.measure.harness.MeasuredTrace`.

    Groups: prefill events (occupancy 1, batch-1 compute), decode events per
    observed batch occupancy, and request-level in-service times per rounded
    mean occupancy (the group :meth:`Tier.from_measured` consumes). Groups
    smaller than ``min_group`` are dropped — a 3-sample variance classifies
    noise, not a distribution.
    """
    from repro.serving.engine import ServiceEvent

    events = [ServiceEvent(*e) for e in trace.events]
    groups: dict[tuple[str, int], list[float]] = {}
    for ev in events:
        if ev.phase == "prefill":
            groups.setdefault(("prefill", 1), []).append(ev.duration_s)
        elif ev.phase == "decode":
            groups.setdefault(("decode", int(ev.occupancy)), []).append(ev.duration_s)
    for r in trace.requests:
        groups.setdefault(("request", r.occupancy), []).append(r.service_s)

    fits = []
    for (phase, occ) in sorted(groups, key=lambda k: (PHASES.index(k[0]), k[1])):
        samples = groups[(phase, occ)]
        if len(samples) < min_group:
            continue
        fits.append(fit_samples(samples, phase=phase, occupancy=occ, seed=seed))
    if not fits:
        raise ValueError(
            f"trace produced no fit group with >= {min_group} samples")
    return fits
