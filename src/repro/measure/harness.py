"""Profiling harness: drive the real Engine under PoissonWorkload, record a trace.

The harness owns the clock. In ``"simulated"`` mode every engine op is
charged a seeded cost-model duration (:class:`SimulatedTimer`) so a run is
bit-replayable — same :class:`HarnessConfig` => identical trace — which is
what lets CI gate analytic-vs-measured latency deterministically. In
``"wall"`` mode durations come from ``time.perf_counter`` around
``block_until_ready`` (real hardware in the loop); the request/event
*structure* is still seeded, only the durations float.

Either way the engine itself is real: prompts run through the jitted
prefill/decode path, tokens are argmax-decoded, slots and queues behave
exactly as in serving. The simulated clock replaces *when* things finish,
never *what* the engine computes.
"""

from __future__ import annotations

import json
from dataclasses import asdict, dataclass
from pathlib import Path
from typing import Any, Callable, Mapping

import numpy as np

__all__ = [
    "HarnessConfig",
    "SimulatedTimer",
    "RequestRecord",
    "MeasuredTrace",
    "run_harness",
]

TRACE_VERSION = 1
_EPS = 1e-12

CLOCKS = ("simulated", "wall")


@dataclass(frozen=True)
class HarnessConfig:
    """One profiling run, fully specified (the replay key for a trace).

    ``arrival_rate=None`` derives lambda from ``target_rho``: the expected
    request service time comes from the cost model (simulated clock) or a
    short unrecorded calibration run (wall clock), and lambda is set so the
    engine sits at the requested utilisation — profiling at a known rho is
    what makes the queueing comparison meaningful.
    """

    arch: str
    slots: int = 1
    max_seq: int = 64
    reduced: bool = True  # cfg.reduced(): tiny CPU-runnable proxy of the arch
    seq_chunk: int = 8
    clock: str = "simulated"  # "simulated" (seeded, replayable) | "wall"
    seed: int = 0
    n_requests: int = 240
    arrival_rate: float | None = None  # requests/s; None -> from target_rho
    target_rho: float = 0.45
    calibrate_requests: int = 8  # wall clock: unrecorded service-time probe
    # workload shape
    prompt_len: int = 8
    prompt_len_jitter: int = 2
    max_new_tokens: int = 6
    new_tokens_geometric_p: float = 0.35
    # simulated-clock cost model (see SimulatedTimer)
    device_flops: float = 5.0e12
    overhead_s: float = 5.0e-4
    timing_cv2: float = 0.25

    def __post_init__(self):
        if self.clock not in CLOCKS:
            raise ValueError(f"clock must be one of {CLOCKS}, got {self.clock!r}")
        if self.slots < 1:
            raise ValueError(f"slots must be >= 1, got {self.slots}")
        if self.n_requests < 1:
            raise ValueError(f"n_requests must be >= 1, got {self.n_requests}")
        if self.arrival_rate is not None and not self.arrival_rate > 0:
            raise ValueError(f"arrival_rate must be > 0, got {self.arrival_rate}")
        if not 0.0 < self.target_rho < 1.0:
            raise ValueError(f"target_rho must be in (0, 1), got {self.target_rho}")
        if self.timing_cv2 < 0:
            raise ValueError(f"timing_cv2 must be >= 0, got {self.timing_cv2}")
        if not self.device_flops > 0 or self.overhead_s < 0:
            raise ValueError("device_flops must be > 0 and overhead_s >= 0")

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "HarnessConfig":
        return cls(**dict(d))


class SimulatedTimer:
    """Seeded service-time model plugged into ``Engine(timer=...)``.

    Charges each engine op a linear cost-model duration scaled by i.i.d.
    gamma jitter with unit mean and squared coefficient of variation
    ``cv2``:

        prefill(L tokens):   (overhead + L * flop_per_token / device_flops) * G
        decode(m slots):     (overhead + m * flop_per_token / device_flops) * G

    ``flop_per_token = 2 * active_params`` (the standard 2N forward cost,
    from ``perf.flops.param_counts``), so larger zoo configs are properly
    slower. The gamma jitter gives the service distribution a known SCV for
    the fit layer to recover, while keeping every draw seeded — the whole
    point of the simulated clock is that reruns are byte-identical.
    """

    def __init__(self, cfg, *, seed: int = 0, device_flops: float = 5.0e12,
                 overhead_s: float = 5.0e-4, cv2: float = 0.25):
        from repro.perf.flops import param_counts

        _, active = param_counts(cfg)
        self.flop_per_token = 2.0 * float(active)
        self.device_flops = float(device_flops)
        self.overhead_s = float(overhead_s)
        self.cv2 = float(cv2)
        self.rng = np.random.default_rng(seed)

    def expected_seconds(self, phase: str, *, tokens: int, occupancy: int) -> float:
        """Mean duration of one op (jitter has unit mean)."""
        return self.overhead_s + tokens * self.flop_per_token / self.device_flops

    def __call__(self, phase: str, run: Callable[[], Any], *,
                 tokens: int, occupancy: int) -> tuple[Any, float]:
        out = run()  # the real engine op still executes
        dt = self.expected_seconds(phase, tokens=tokens, occupancy=occupancy)
        if self.cv2 > 0:
            dt *= float(self.rng.gamma(1.0 / self.cv2, self.cv2))
        return out, dt


@dataclass(frozen=True)
class RequestRecord:
    """Per-request timeline extracted from the engine's service log."""

    rid: int
    arrival_s: float
    prompt_len: int
    n_tokens: int
    t_admit: float
    t_first_token: float
    t_done: float
    prefill_s: float
    decode_s: float  # sum of the decode steps this request participated in
    n_decode: int
    mean_occupancy: float  # mean decode-batch size over those steps

    @property
    def queue_wait_s(self) -> float:
        return self.t_admit - self.arrival_s

    @property
    def latency_s(self) -> float:
        return self.t_done - self.arrival_s

    @property
    def service_s(self) -> float:
        """In-service wall time (admission to completion) — the request-level
        service the latency models reason about. >= prefill_s + decode_s when
        other requests' prefills interleave (head-of-line batching)."""
        return self.t_done - self.t_admit

    @property
    def occupancy(self) -> int:
        """Rounded mean decode occupancy — the fit-group key."""
        return int(round(self.mean_occupancy)) if self.n_decode else 1

    def to_dict(self) -> dict:
        return asdict(self)

    @classmethod
    def from_dict(cls, d: Mapping) -> "RequestRecord":
        return cls(**dict(d))


@dataclass(frozen=True)
class MeasuredTrace:
    """A completed profiling run: resolved config + per-request records +
    the raw engine service log (compile-flagged events excluded)."""

    harness: HarnessConfig
    arrival_rate: float  # resolved lambda actually used
    requests: tuple[RequestRecord, ...]
    events: tuple[tuple, ...]  # ServiceEvent rows (t, phase, dur, occ, rid, tokens, compile)
    version: int = TRACE_VERSION

    def latencies(self) -> np.ndarray:
        return np.array([r.latency_s for r in self.requests])

    def to_dict(self) -> dict:
        return {
            "version": self.version,
            "harness": self.harness.to_dict(),
            "arrival_rate": self.arrival_rate,
            "requests": [r.to_dict() for r in self.requests],
            "events": [list(e) for e in self.events],
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "MeasuredTrace":
        return cls(
            harness=HarnessConfig.from_dict(d["harness"]),
            arrival_rate=float(d["arrival_rate"]),
            requests=tuple(RequestRecord.from_dict(r) for r in d["requests"]),
            events=tuple(tuple(e) for e in d["events"]),
            version=int(d.get("version", TRACE_VERSION)),
        )

    def save(self, path: str | Path) -> Path:
        path = Path(path)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(self.to_dict(), indent=2, sort_keys=True) + "\n")
        return path

    @classmethod
    def load(cls, path: str | Path) -> "MeasuredTrace":
        return cls.from_dict(json.loads(Path(path).read_text()))


# ---------------------------------------------------------------------------
# running
# ---------------------------------------------------------------------------


def _expected_workload(hc: HarnessConfig) -> tuple[float, float]:
    """(E[prompt_len], E[new_tokens]) of the configured workload, estimated
    from a large seeded sample of the same draw logic (exact enough for
    setting a target utilisation; derived from hc only, so deterministic)."""
    rng = np.random.default_rng(hc.seed + 104729)
    n = 4096
    L = np.full(n, hc.prompt_len, dtype=np.int64)
    if hc.prompt_len_jitter:
        L = L + rng.integers(-hc.prompt_len_jitter, hc.prompt_len_jitter + 1, size=n)
    if hc.new_tokens_geometric_p > 0:
        nt = 1 + rng.geometric(hc.new_tokens_geometric_p, size=n)
        nt = np.minimum(nt, hc.max_new_tokens)
    else:
        nt = np.full(n, hc.max_new_tokens, dtype=np.int64)
    return float(L.mean()), float(nt.mean())


def _resolve_arrival_rate(hc: HarnessConfig, eng, timer: SimulatedTimer | None,
                          make_request) -> float:
    """lambda for the run: explicit, or target_rho * slots / E[request service]."""
    if hc.arrival_rate is not None:
        return float(hc.arrival_rate)
    e_len, e_new = _expected_workload(hc)
    if timer is not None:
        service = timer.expected_seconds("prefill", tokens=int(round(e_len)), occupancy=1)
        service += (e_new - 1.0) * timer.expected_seconds("decode", tokens=1, occupancy=1)
    else:
        # wall clock: probe the hardware with a short back-to-back burst
        # (unrecorded; the caller clears the service log afterwards)
        for k in range(hc.calibrate_requests):
            eng.submit(make_request(rid=-(k + 1)))
        eng.drain()
        probes = [r.service_s for r in
                  (_request_records(eng.completed, eng.service_log)
                   if eng.completed else [])]
        service = float(np.mean(probes)) if probes else 1e-3
        eng.completed.clear()
    return hc.target_rho * hc.slots / max(service, _EPS)


def _request_records(reqs, events) -> list[RequestRecord]:
    """Join completed requests against the service log.

    A request's decode steps are exactly the decode events whose start time
    falls in [t_first_token, t_done): every decode step in that window ran
    the full active batch, which included this request."""
    prefills = {ev.rid: ev for ev in events if ev.phase == "prefill"}
    decodes = [ev for ev in events if ev.phase == "decode"]
    out = []
    for r in sorted(reqs, key=lambda r: r.rid):
        if r.t_done is None or r.rid not in prefills:
            continue
        pre = prefills[r.rid]
        dec = [ev for ev in decodes
               if r.t_first_token - _EPS <= ev.t < r.t_done - _EPS]
        out.append(RequestRecord(
            rid=r.rid,
            arrival_s=float(r.arrival_s),
            prompt_len=int(len(r.prompt)),
            n_tokens=int(len(r.tokens_out)),
            t_admit=float(r.t_admit),
            t_first_token=float(r.t_first_token),
            t_done=float(r.t_done),
            prefill_s=float(pre.duration_s),
            decode_s=float(sum(ev.duration_s for ev in dec)),
            n_decode=len(dec),
            mean_occupancy=float(np.mean([ev.occupancy for ev in dec])) if dec else 1.0,
        ))
    return out


def run_harness(hc: HarnessConfig, *, tracer=None) -> MeasuredTrace:
    """Run one profiling experiment end to end and return its trace.

    Event loop: arrivals with ``arrival_s <= t`` are submitted, the engine
    ticks on the harness clock, and ``t`` advances by the service time the
    tick consumed (the engine serialises its ops, so elapsed time is exactly
    the sum of the tick's event durations). When the system empties, ``t``
    jumps to the next arrival — idle time costs nothing.

    ``tracer`` (a ``repro.obs.Tracer``) records the queue/prefill/decode/
    respond lifecycle of every request on the harness clock — with the
    simulated clock the emitted span stream is byte-stable per seed, exactly
    like the trace artifact itself. Calibration probes are excluded (the
    tracer is attached after calibration, mirroring service_log.clear()).
    """
    import jax

    from repro.configs import get_config
    from repro.models import lm
    from repro.serving.engine import Engine, ServeConfig
    from repro.serving.workload import PoissonWorkload, WorkloadConfig

    cfg = get_config(hc.arch)
    if hc.reduced:
        cfg = cfg.reduced(seq_chunk=hc.seq_chunk)
    params = lm.init_model(cfg, jax.random.PRNGKey(hc.seed))
    timer = None
    if hc.clock == "simulated":
        timer = SimulatedTimer(cfg, seed=hc.seed + 1, device_flops=hc.device_flops,
                               overhead_s=hc.overhead_s, cv2=hc.timing_cv2)
    eng = Engine(cfg, params, ServeConfig(slots=hc.slots, max_seq=hc.max_seq),
                 timer=timer)

    lo, hi = hc.prompt_len - hc.prompt_len_jitter, hc.prompt_len + hc.prompt_len_jitter
    eng.warmup(range(lo, hi + 1))

    probe_rng = np.random.default_rng(hc.seed + 2)

    def probe_request(rid: int):
        from repro.serving.engine import Request

        return Request(rid=rid,
                       prompt=probe_rng.integers(0, cfg.vocab_size, size=hc.prompt_len)
                       .astype(np.int32),
                       max_new_tokens=hc.max_new_tokens)

    lam = _resolve_arrival_rate(hc, eng, timer, probe_request)
    eng.service_log.clear()  # drop any calibration events
    if tracer is not None:
        eng.tracer = tracer
        eng._trace = getattr(tracer, "enabled", True)

    wc = WorkloadConfig(
        arrival_rate=lam,
        prompt_len=hc.prompt_len,
        prompt_len_jitter=hc.prompt_len_jitter,
        max_new_tokens=hc.max_new_tokens,
        new_tokens_geometric_p=hc.new_tokens_geometric_p,
        vocab=cfg.vocab_size,
        seed=hc.seed,
    )
    reqs = PoissonWorkload(wc).take(hc.n_requests)

    t, i, n = 0.0, 0, len(reqs)
    while len(eng.completed) < n:
        while i < n and reqs[i].arrival_s <= t + _EPS:
            eng.submit(reqs[i])
            i += 1
        if not eng.queue and not any(r is not None for r in eng.active):
            t = reqs[i].arrival_s  # idle: jump to the next arrival
            continue
        k0 = len(eng.service_log)
        eng.tick(now=t)
        t += sum(ev.duration_s for ev in eng.service_log[k0:])

    steady = [ev for ev in eng.service_log if not ev.compile]
    return MeasuredTrace(
        harness=hc,
        arrival_rate=lam,
        requests=tuple(_request_records(eng.completed, steady)),
        events=tuple(tuple(ev) for ev in steady),
    )
