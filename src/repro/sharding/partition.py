"""Logical-axis sharding: t5x-style rules mapping logical axis names to mesh axes.

Model code annotates parameters and activations with *logical* axis names
("batch", "heads", "ff", ...). At launch, a ``ShardingRules`` context resolves
those to mesh axes and applies ``with_sharding_constraint``. Outside any
context (CPU smoke tests) every hint is a no-op, so model code is
mesh-agnostic.

Baseline rules (DESIGN.md §6): Megatron-style tensor parallelism over
"model", batch data-parallel over ("pod", "data"), optimizer state further
sharded over "data" (ZeRO-1, see training/optimizer.py).
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from dataclasses import dataclass, field, replace

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["ShardingRules", "use_rules", "current_rules", "hint", "logical_to_spec", "named_sharding"]


@dataclass(frozen=True)
class ShardingRules:
    """Map logical axis name -> mesh axis (str | tuple | None)."""

    mesh: jax.sharding.Mesh
    rules: dict[str, object] = field(default_factory=dict)

    @staticmethod
    def default(mesh: jax.sharding.Mesh, *, seq_parallel: bool = False) -> "ShardingRules":
        has_pod = "pod" in mesh.axis_names
        batch_axes = ("pod", "data") if has_pod else ("data",)
        rules = {
            "batch": batch_axes,  # batch dim of activations / data
            "seq": "model" if seq_parallel else None,  # residual-stream sequence dim
            "seq_inner": None,  # interior activations (heads/ff already use model)
            "kv_seq": None,  # key/value sequence dim (cache; see decode rules)
            "embed": None,  # d_model dim of activations & params
            "heads": "model",  # attention heads (param + activation)
            "qkv": "model",  # flattened heads*head_dim param dim
            "kv": "model",  # flattened kv_heads*head_dim param dim
            "ff": "model",  # MLP hidden
            "vocab": "model",  # embedding/logits vocab dim
            "expert": "model",  # MoE expert dim
            "expert_ff": "data",  # per-expert hidden (480B-class stacks must
            # shard over data too or they exceed per-device HBM)
            "zero": "data",  # ZeRO-1 optimizer-state axis
            "mlstm_dk": "model",  # xLSTM matrix-memory key dim
            "cache_batch": batch_axes,  # KV cache batch dim
            "cache_kv": "model",  # KV cache flattened kv feature dim
            "cache_seq": None,  # KV cache sequence dim (long_500k: "data")
            "conv_state": None,
        }
        return ShardingRules(mesh, rules)

    def with_overrides(self, **overrides) -> "ShardingRules":
        new = dict(self.rules)
        new.update(overrides)
        return replace(self, rules=new)

    def spec(self, logical_axes: tuple) -> P:
        parts = []
        for ax in logical_axes:
            if ax is None:
                parts.append(None)
            else:
                parts.append(self.rules.get(ax))
        return P(*parts)


def rules_for_cell(cfg, shape, mesh, *, seq_parallel: bool = False) -> ShardingRules:
    """Resolve rules for one (arch x shape x mesh) cell, honouring divisibility.

    * batch axes: the largest prefix of (pod, data) whose product divides the
      global batch (long_500k's batch=1 shards nothing);
    * vocab: replicated when vocab_size is not divisible by the model axis
      (seamless 256206, internvl2 151655);
    * decode caches: sequence-sharded over "model" (split-KV decode); for
      unsharded-batch cells over every axis that divides the cache length.
    """
    sizes = dict(mesh.shape)
    model = sizes.get("model", 1)
    # sequence parallelism for full-sequence steps: residual stream sharded
    # over model (Megatron-SP); decode has seq=1 so it never applies.
    # cfg.seq_parallel: "on"/"off" overrides the heuristic (§Perf lever —
    # prefill has no remat, so SP only buys per-layer all-gathers there).
    sp_mode = getattr(cfg, "seq_parallel", "auto")
    if sp_mode == "off":
        seq_parallel = False
    elif sp_mode == "on":
        seq_parallel = shape.seq_len % model == 0
    else:
        seq_parallel = seq_parallel or (
            shape.kind in ("train", "prefill") and shape.seq_len % model == 0
        )
    rules = ShardingRules.default(mesh, seq_parallel=seq_parallel)

    # batch axes
    cand = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    batch_axes: tuple = ()
    prod = 1
    for ax in cand:
        if shape.global_batch % (prod * sizes[ax]) == 0:
            batch_axes += (ax,)
            prod *= sizes[ax]
    batch_rule = batch_axes if batch_axes else None
    overrides: dict = {"batch": batch_rule, "cache_batch": batch_rule}

    if cfg.padded_vocab % model:  # padded to 256-multiples; never on v5e meshes
        overrides["vocab"] = None
    if cfg.num_experts and cfg.d_ff % sizes.get("data", 1):
        overrides["expert_ff"] = None
    if cfg.num_experts and shape.kind == "decode":
        # §Perf (jamba decode cell): expert weights sharded over "data" force
        # a full expert-stack all-gather EVERY decode step (~11 GB/dev wire).
        # Inference has no optimizer state, so the weights fit resident.
        overrides["expert_ff"] = None

    if shape.kind == "decode":
        cache_axes: tuple = ()
        cprod = 1
        lens = [shape.seq_len]
        if cfg.has_mixer("attn_local"):
            lens.append(min(shape.seq_len, cfg.window_size))
        axis_order = ("model",) if batch_axes else tuple(
            a for a in ("pod", "data", "model") if a in mesh.axis_names
        )
        for ax in axis_order:
            if all(l % (cprod * sizes[ax]) == 0 for l in lens):
                cache_axes += (ax,)
                cprod *= sizes[ax]
        overrides["cache_seq"] = cache_axes if cache_axes else None
    elif shape.kind == "prefill":
        # emit caches already in decode layout
        if shape.seq_len % model == 0 and (
            not cfg.has_mixer("attn_local") or min(shape.seq_len, cfg.window_size) % model == 0
        ):
            overrides["cache_seq"] = "model"

    return rules.with_overrides(**overrides)


_local = threading.local()


@contextmanager
def use_rules(rules: ShardingRules | None):
    prev = getattr(_local, "rules", None)
    _local.rules = rules
    try:
        yield rules
    finally:
        _local.rules = prev


def current_rules() -> ShardingRules | None:
    return getattr(_local, "rules", None)


def logical_to_spec(logical_axes: tuple) -> P | None:
    rules = current_rules()
    if rules is None:
        return None
    return rules.spec(logical_axes)


def hint(x, *logical_axes):
    """with_sharding_constraint under the active rules; identity otherwise."""
    rules = current_rules()
    if rules is None:
        return x
    if x.ndim != len(logical_axes):
        raise ValueError(f"rank {x.ndim} vs logical axes {logical_axes}")
    spec = rules.spec(logical_axes)
    return jax.lax.with_sharding_constraint(x, NamedSharding(rules.mesh, spec))


def named_sharding(logical_axes: tuple) -> NamedSharding | None:
    rules = current_rules()
    if rules is None:
        return None
    return NamedSharding(rules.mesh, rules.spec(logical_axes))
