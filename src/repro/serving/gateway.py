"""Offload gateway: the paper's Algorithm 1 embedded in the serving stack.

The gateway fronts one *device-tier* engine and E *edge-tier* engines
separated by a modelled network path. Per epoch it snapshots telemetry
(sliding-window arrival rate, EWMA bandwidth, per-edge aggregate load +
service moments), asks ``AdaptiveOffloadManager`` for the argmin strategy,
and routes the epoch's requests accordingly. Service-time estimates come from
the engines' own profiled ticks (paper §4.2) or, before any profile exists,
from the roofline estimator (§3.2 "prediction").

This is the deployable form of the paper's resource manager: the same object
drives the Fig. 6 (network dynamics) and Fig. 7 (multi-tenant) case studies
in benchmarks/, with the discrete-event simulator standing in for wall-clock
engines so the studies are deterministic.
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Sequence

import numpy as np

from repro.core.latency import NetworkPath, ServiceModel, Tier, Workload
from repro.core.manager import ON_DEVICE, AdaptiveOffloadManager, Decision, EdgeServerState
from repro.core.telemetry import EwmaEstimator, SlidingRateEstimator, WindowedMoments

__all__ = ["EdgeHandle", "OffloadGateway"]


@dataclass
class EdgeHandle:
    """One edge server as the gateway tracks it."""

    name: str
    service_mean_s: float  # current estimate for THIS workload on the edge
    parallelism_k: float = 1.0
    service_var_s: float = 0.0  # Var[s] of THIS workload's service on the edge
    background_rate: float = 0.0  # other tenants' aggregate lambda (obs.)
    background_service_s: float = 0.0
    background_service_var: float = 0.0
    bandwidth_Bps: float | None = None  # per-edge path override (else device B)
    arrivals: SlidingRateEstimator = field(default_factory=lambda: SlidingRateEstimator(30.0))
    service: WindowedMoments = field(default_factory=WindowedMoments)
    load_reports: EwmaEstimator = field(default_factory=lambda: EwmaEstimator(alpha=0.5))

    @classmethod
    def from_spec(cls, spec) -> "EdgeHandle":
        """Build a handle from a declarative ``repro.core.EdgeSpec`` — the
        spec's background tenants seed the handle's load/mixture estimates,
        which live telemetry then updates, and the own-stream variance is the
        one the tier's service model implies (matching ``analytic()``).

        Note the arrival-rate semantics differ from ``EdgeSpec.to_state()``
        by design: the gateway models the edge's *observed* load, so the own
        stream only enters the aggregate once requests are actually routed
        there (``arrivals`` estimator), whereas ``to_state()`` answers the
        declarative what-if with the own stream always included."""
        from repro.core.multitenant import aggregate_streams
        from repro.core.scenario import implied_service_var

        if spec.background:
            agg = aggregate_streams(spec.background)
            bg_rate, bg_mean, bg_var = agg.arrival_rate, agg.service_mean_s, agg.service_var
        else:
            # no declared tenants: seed the background TEMPLATE with the
            # edge's own service moments (the fleet bg_template convention),
            # so a later rate-only load report prices the load like this
            # workload instead of at zero service time. Inert until a report
            # arrives — state() ignores the template while the rate is 0.
            bg_rate = 0.0
            bg_mean = spec.tier.service_time_s
            bg_var = implied_service_var(spec.tier)
        return cls(
            name=spec.tier.name,
            service_mean_s=spec.tier.service_time_s,
            parallelism_k=spec.tier.parallelism_k,
            service_var_s=implied_service_var(spec.tier),
            background_rate=bg_rate,
            background_service_s=bg_mean,
            background_service_var=bg_var,
            bandwidth_Bps=spec.bandwidth_Bps,
        )

    def observe_load(
        self,
        background_rate: float,
        service_mean_s: float | None = None,
        service_var: float | None = None,
    ) -> None:
        """Edge load report (§4.2): EWMA the reported aggregate *other-tenant*
        rate into this handle's background estimate — the same lagged view the
        closed-loop cluster simulator's clients act on. The optional moments
        refresh the background mixture template when the edge reports what the
        load is made of; without them the current template holds, falling back
        to this workload's own service moments if the template is degenerate
        (a hand-built handle with no moments) — reported load must never be
        priced at zero service time."""
        if background_rate < 0:
            raise ValueError("background rate report must be non-negative")
        if service_mean_s is not None and service_mean_s <= 0:
            raise ValueError("background service mean report must be positive")
        if service_var is not None and service_var < 0:
            raise ValueError("background service variance report must be non-negative")
        self.background_rate = self.load_reports.update(float(background_rate))
        if service_mean_s is None and self.background_service_s <= 0.0:
            self.background_service_s = self.service_mean_s
            self.background_service_var = self.service_var_s
        if service_mean_s is not None:
            self.background_service_s = float(service_mean_s)
        if service_var is not None:
            self.background_service_var = float(service_var)

    def state(self, wl_service_mean: float | None = None) -> EdgeServerState:
        mine = wl_service_mean if wl_service_mean is not None else self.service_mean_s
        lam_bg = self.background_rate
        lam_own = self.arrivals.rate() if self.arrivals else 0.0
        lam_total = lam_bg + lam_own
        # aggregate mixture moments across tenants (paper §3.4)
        if lam_total > 0 and lam_bg > 0:
            w_bg = lam_bg / lam_total
            mean = w_bg * self.background_service_s + (1 - w_bg) * mine
            second = w_bg * (
                self.background_service_var + self.background_service_s**2
            ) + (1 - w_bg) * (self.service_var_s + mine**2)
            var = max(0.0, second - mean**2)
        else:
            mean, var = mine, self.service_var_s
        return EdgeServerState(
            name=self.name,
            service_rate=1.0 / max(mean, 1e-9),
            arrival_rate=lam_total,
            service_time_s=mine,
            service_var=var,
            parallelism_k=self.parallelism_k,
            bandwidth_Bps=self.bandwidth_Bps,
        )


class OffloadGateway:
    """Routes a request stream between on-device and edge execution."""

    def __init__(
        self,
        device_tier: Tier,
        edges: Sequence[EdgeHandle],
        wl: Workload,
        *,
        bandwidth_Bps: float,
        epoch_s: float = 1.0,
        hysteresis: float = 0.0,
        return_results: bool = True,
        deadline_timeout: Callable[[float], float] | None = None,
        auditor=None,
        tracer=None,
        metrics=None,
    ):
        self.device = device_tier
        self.edges = list(edges)
        self.wl = wl
        self.epoch_s = epoch_s
        # observability (repro.obs, all duck-typed): the manager records the
        # decision audit + decide span; the gateway adds the modelled transfer
        # span and feeds the metrics registry
        self.tracer = tracer
        self.metrics = metrics
        self.manager = AdaptiveOffloadManager(
            device_tier, hysteresis=hysteresis, return_results=return_results,
            auditor=auditor, tracer=tracer, audit_source="gateway",
        )
        self.bandwidth = EwmaEstimator(alpha=0.5, initial=bandwidth_Bps)
        self.arrivals = SlidingRateEstimator(window_s=30.0)
        self.decisions: list[Decision] = []
        self.deadline_timeout = deadline_timeout
        self.redispatches = 0

    @classmethod
    def from_scenario(cls, scn, **kwargs) -> "OffloadGateway":
        """Build the deployable gateway from the same validated
        ``repro.core.Scenario`` that drives ``analytic``/``simulate`` — no
        per-consumer re-assembly of tiers, handles, or bandwidths."""
        kwargs.setdefault("return_results", scn.return_results)
        return cls(
            scn.device,
            [EdgeHandle.from_spec(e) for e in scn.edges],
            scn.workload,
            bandwidth_Bps=float(np.asarray(scn.network.bandwidth_Bps)),
            **kwargs,
        )

    # -- telemetry inputs ---------------------------------------------------
    def observe_bandwidth(self, measured_Bps: float) -> None:
        self.bandwidth.update(measured_Bps)

    def observe_arrival(self, t: float) -> None:
        self.arrivals.record(t)

    # -- epoch decision (Algorithm 1) ----------------------------------------
    def decide(self, now: float) -> Decision:
        measured = self.arrivals.rate(now)
        lam = measured if measured > 0 else self.wl.arrival_rate
        # one decision path: the manager's step() hook builds the snapshot and
        # runs Algorithm 1 for both this gateway and repro.fleet.replay
        d = self.manager.step(
            now,
            {
                "workload": self.wl,
                "lam_dev": lam,
                "bandwidth_Bps": self.bandwidth.value,
                "edges": [e.state() for e in self.edges],
            },
        )
        self.decisions.append(d)
        if self.tracer is not None and d.edge_index != ON_DEVICE:
            # the modelled transfer this decision commits the epoch's
            # requests to: request leg out now, response leg back after the
            # edge's service (mean-model stamps, same clock as the decision)
            edge = self.edges[d.edge_index]
            b = edge.bandwidth_Bps if edge.bandwidth_Bps is not None \
                else self.bandwidth.value
            if b > 0:
                track = f"edge[{d.edge_index}]"
                t_req = self.wl.req_bytes / b
                self.tracer.span(
                    t=now, dur=t_req, name="transfer:request", cat="transfer",
                    track=track, bytes=self.wl.req_bytes, bandwidth_Bps=b)
                if self.manager.return_results and self.wl.res_bytes > 0:
                    self.tracer.span(
                        t=now + t_req + edge.service_mean_s,
                        dur=self.wl.res_bytes / b, name="transfer:response",
                        cat="transfer", track=track, bytes=self.wl.res_bytes,
                        bandwidth_Bps=b)
        if self.metrics is not None:
            self.metrics.counter("gateway.decisions").inc()
            if d.edge_index != ON_DEVICE:
                self.metrics.counter("gateway.offloaded_epochs").inc()
            self.metrics.gauge("gateway.bandwidth_Bps").set(self.bandwidth.value)
            self.metrics.gauge("gateway.arrival_rate").set(lam)
            if np.isfinite(d.predicted_latency_s):
                self.metrics.histogram(
                    "gateway.predicted_latency_s").record(d.predicted_latency_s)
        return d

    # -- straggler mitigation -------------------------------------------------
    def check_deadline(self, predicted_s: float, elapsed_s: float) -> bool:
        """True -> re-dispatch: the request blew through its model-predicted
        deadline (default 5x predicted mean ~= an M/M/1 p99)."""
        timeout = (
            self.deadline_timeout(predicted_s)
            if self.deadline_timeout
            else 5.0 * predicted_s
        )
        if elapsed_s > timeout:
            self.redispatches += 1
            return True
        return False

    @property
    def switches(self) -> int:
        return self.manager.switches
