"""Serving engine: batched prefill + decode with KV caches.

A deliberately compact continuous-batching engine ("batching-lite"): requests
are admitted into fixed-capacity decode slots; each engine tick runs one
decode step for every active slot; finished sequences free their slot for the
admission queue. Prefill runs per-request (batch=1) and writes the slot's
cache region.

The engine is the paper's "accelerator": its measured service times feed the
queueing models, and the gateway (serving/gateway.py) applies Algorithm 1 to
route between a device-tier engine and edge-tier engines. Timing is
measurement-grade (repro.measure relies on it):

  * every service stamp is taken AFTER ``jax.block_until_ready`` on the op's
    outputs — JAX dispatch is asynchronous, so a bare ``time.*`` pair around
    a jitted call measures dispatch latency, not device compute;
  * JIT compile time is kept out of steady-state service: :meth:`warmup`
    compiles the prefill/decode executables up front, and any cold call that
    does slip through is flagged ``compile=True`` in the service log and
    excluded from :meth:`observed_service_stats`;
  * a pluggable ``timer`` lets the measurement harness substitute a seeded,
    deterministic service-time model for the wall clock (the "simulated
    clock" mode of ``repro.measure.harness``) while the engine still runs the
    real model for token-level correctness.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Iterable, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm

__all__ = ["Request", "ServeConfig", "ServiceEvent", "Engine"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    arrival_s: float = 0.0
    # filled by the engine:
    tokens_out: list = field(default_factory=list)
    t_admit: float | None = None  # prefill start (queue wait ends here)
    t_first_token: float | None = None
    t_done: float | None = None

    @property
    def latency_s(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.arrival_s

    @property
    def queue_wait_s(self) -> float | None:
        return None if self.t_admit is None else self.t_admit - self.arrival_s


@dataclass(frozen=True)
class ServeConfig:
    slots: int = 4  # concurrent decode slots
    max_seq: int = 512  # cache capacity per slot
    greedy: bool = True


class ServiceEvent(NamedTuple):
    """One timed engine operation in the service log.

    ``t`` is the operation's start on the engine clock (simulated or wall);
    ``occupancy`` is the compute batch the accelerator saw (1 for per-request
    prefill, the number of active slots for a decode step). ``compile=True``
    marks a wall-clocked call whose executable was cold (JIT compile included
    in ``duration_s``) — excluded from steady-state statistics.
    """

    t: float
    phase: str  # "prefill" | "decode"
    duration_s: float
    occupancy: int
    rid: int  # request id for prefill; -1 for batched decode steps
    tokens: int  # prompt tokens (prefill) / tokens emitted (decode)
    compile: bool = False


# timer(phase, run, tokens=..., occupancy=...) -> (run's result, seconds)
Timer = Callable[..., tuple[Any, float]]


class Engine:
    """Single-model serving engine over the lm prefill/decode steps.

    ``timer`` (optional) replaces the wall clock for service durations: the
    engine still executes the real jitted ops, but charges each one the
    seconds the timer returns. ``repro.measure.harness.SimulatedTimer`` uses
    this for seeded, replayable profiling runs.
    """

    def __init__(self, cfg: ModelConfig, params: Any, sc: ServeConfig,
                 timer: Timer | None = None, tracer=None):
        self.cfg = cfg
        self.sc = sc
        self.params = params
        self.timer = timer
        # repro.obs request tracing (duck-typed; serving never imports obs).
        # _trace is the single predicate every hot-path emission site checks:
        # tracer=None and Tracer(enabled=False) cost exactly one bool test.
        self.tracer = tracer
        self._trace = tracer is not None and getattr(tracer, "enabled", True)
        self._decode = jax.jit(
            lambda p, tok, pos, caches: lm.decode_step(p, cfg, tok, pos, caches)
        )
        self._prefill = jax.jit(
            lambda p, tokens: lm.prefill(p, cfg, tokens)
        )
        # slot state
        B, S = sc.slots, sc.max_seq
        self.caches = self._zero_caches(B, S)
        self.positions = np.zeros(B, np.int32)  # next position per slot
        self.active: list[Request | None] = [None] * B
        self.remaining = np.zeros(B, np.int32)
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.service_log: list[ServiceEvent] = []
        # executables already compiled (prefill by prompt length; one decode
        # shape total) — cold wall-clocked calls are flagged in the log
        self._warm_prefill: set[int] = set()
        self._warm_decode = False

    def _zero_caches(self, batch: int, seq: int):
        from repro.models.params import init_params
        from repro.models.lm import cache_template

        tpl = cache_template(self.cfg, batch, seq, enc_len=seq if self.cfg.is_encdec else 0)
        return init_params(tpl, jax.random.PRNGKey(0), jnp.dtype(self.cfg.dtype))

    # ------------------------------------------------------------------
    def _timed(self, phase: str, run: Callable[[], Any], *,
               tokens: int, occupancy: int) -> tuple[Any, float]:
        """Run ``run`` and return (result, service seconds). Wall mode blocks
        on the result BEFORE the closing stamp (async dispatch otherwise makes
        the measurement a dispatch time, not a service time)."""
        if self.timer is not None:
            out, dt = self.timer(phase, run, tokens=tokens, occupancy=occupancy)
            return out, float(dt)
        t0 = time.perf_counter()
        out = jax.block_until_ready(run())
        return out, time.perf_counter() - t0

    def warmup(self, prompt_lens: Iterable[int] = (), *, decode: bool = True) -> None:
        """Compile the jitted executables outside the measured path.

        JAX specialises ``prefill`` per prompt length, so pass every length
        the workload can draw. Compile-time is the dominant first-call cost
        (seconds vs millisecond service times) and would otherwise pollute
        any measured mean. Runs on scratch inputs; engine state is untouched.
        """
        for L in sorted({int(x) for x in prompt_lens}):
            if L in self._warm_prefill:
                continue
            jax.block_until_ready(
                self._prefill(self.params, jnp.zeros((1, L), jnp.int32)))
            self._warm_prefill.add(L)
        if decode and not self._warm_decode:
            tok = jnp.zeros((self.sc.slots, 1), jnp.int32)
            jax.block_until_ready(
                self._decode(self.params, tok, jnp.int32(0), self.caches))
            self._warm_decode = True

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self, now: float) -> float:
        """Admit queued requests into free slots; returns the advanced clock
        (each prefill occupies the accelerator, so admissions serialise)."""
        for slot in range(self.sc.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            L = len(req.prompt)
            cold = self.timer is None and L not in self._warm_prefill

            def run():
                prompt = jnp.asarray(req.prompt[None], jnp.int32)
                logits, caches = self._prefill(self.params, prompt)
                # write this request's cache into the slot (batch index
                # `slot`) inside the timed region — the copy is device work
                # the request's service genuinely includes
                new = jax.tree.map(
                    lambda full, one: self._write_slot(full, one, slot, L),
                    self.caches,
                    caches,
                )
                return logits, new

            req.t_admit = now
            (logits, new_caches), dt = self._timed(
                "prefill", run, tokens=L, occupancy=1)
            self.caches = new_caches
            self._warm_prefill.add(L)
            next_tok = int(jnp.argmax(logits[0, -1]))
            self.positions[slot] = L
            self.remaining[slot] = req.max_new_tokens - 1
            req.tokens_out.append(next_tok)
            req.t_first_token = now + dt
            self.service_log.append(
                ServiceEvent(now, "prefill", dt, 1, req.rid, L, cold))
            if self._trace:
                track = f"req[{req.rid}]"
                self.tracer.span(
                    t=req.arrival_s, dur=max(0.0, now - req.arrival_s),
                    name="queue", cat="queue", track=track, rid=req.rid)
                self.tracer.span(
                    t=now, dur=dt, name="prefill", cat="prefill", track=track,
                    rid=req.rid, tokens=L, compile=cold)
            now += dt
            if self.remaining[slot] <= 0:
                # single-token request: prefill IS the whole service
                req.t_done = req.t_first_token
                self.completed.append(req)
                if self._trace:
                    self.tracer.instant(
                        t=req.t_done, name="respond", cat="respond",
                        track=f"req[{req.rid}]", rid=req.rid,
                        tokens=len(req.tokens_out), latency_s=req.latency_s)
            else:
                self.active[slot] = req
        return now

    @staticmethod
    def _write_slot(full, one, slot: int, prompt_len: int):
        """Place a single-request cache (leading batch 1) into slot `slot`.

        Sequence-bearing leaves (dim2 = cache capacity) copy the prompt
        prefix; state leaves (mamba/xLSTM) copy wholesale."""
        if full.ndim >= 3 and one.ndim == full.ndim and full.shape[2] != one.shape[2]:
            # kv-style cache: (n_sb, B, S_cap, ...) vs prefill (n_sb, 1, S_p, ...)
            s = min(one.shape[2], full.shape[2])
            return full.at[:, slot : slot + 1, :s].set(one[:, :, :s].astype(full.dtype))
        return full.at[:, slot : slot + 1].set(one.astype(full.dtype))

    # ------------------------------------------------------------------
    def tick(self, now: float | None = None) -> int:
        """Admit + one decode step for all active slots. Returns #active.

        ``now`` is the engine clock at tick start (wall time when omitted);
        completion stamps land at ``now + elapsed service``, so request
        timestamps are event times, not tick-start times.
        """
        now = time.time() if now is None else now
        now = self._admit(now)
        if not any(r is not None for r in self.active):
            return 0
        cold = self.timer is None and not self._warm_decode

        last = np.zeros((self.sc.slots, 1), np.int32)
        for slot, req in enumerate(self.active):
            if req is not None:
                last[slot, 0] = req.tokens_out[-1]
        pos = int(max(self.positions[s] for s, r in enumerate(self.active) if r is not None))
        n_active = sum(r is not None for r in self.active)

        def run():
            return self._decode(self.params, jnp.asarray(last), jnp.int32(pos), self.caches)

        (logits, new_caches), dt = self._timed(
            "decode", run, tokens=n_active, occupancy=n_active)
        self.caches = new_caches
        self._warm_decode = True
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            req.tokens_out.append(int(nxt[slot]))
            self.positions[slot] += 1
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0 or self.positions[slot] >= self.sc.max_seq - 1:
                req.t_done = now + dt
                self.completed.append(req)
                self.active[slot] = None
                if self._trace:
                    self.tracer.instant(
                        t=req.t_done, name="respond", cat="respond",
                        track=f"req[{req.rid}]", rid=req.rid,
                        tokens=len(req.tokens_out), latency_s=req.latency_s)
        self.service_log.append(
            ServiceEvent(now, "decode", dt, n_active, -1, n_active, cold))
        if self._trace:
            self.tracer.span(
                t=now, dur=dt, name="decode", cat="decode", track="engine",
                occupancy=n_active, compile=cold)
        return n_active

    def drain(self) -> None:
        while self.queue or any(r is not None for r in self.active):
            self.tick()

    # ------------------------------------------------------------------
    def observed_service_stats(self) -> tuple[float, float]:
        """(mean, var) of measured per-op service times — the paper's
        profiled service-time input (§4.2). Cold (compile-bearing) calls are
        excluded; they measure the XLA compiler, not the accelerator."""
        durs = [ev.duration_s for ev in self.service_log if not ev.compile]
        if not durs:
            return 0.0, 0.0
        arr = np.array(durs)
        return float(arr.mean()), float(arr.var())
