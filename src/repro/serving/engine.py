"""Serving engine: batched prefill + decode with KV caches.

A deliberately compact continuous-batching engine ("batching-lite"): requests
are admitted into fixed-capacity decode slots; each engine tick runs one
decode step for every active slot; finished sequences free their slot for the
admission queue. Prefill runs per-request (batch=1) and writes the slot's
cache region.

The engine is the paper's "accelerator": its measured service times feed the
queueing models, and the gateway (serving/gateway.py) applies Algorithm 1 to
route between a device-tier engine and edge-tier engines.
"""

from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import lm

__all__ = ["Request", "ServeConfig", "Engine"]


@dataclass
class Request:
    rid: int
    prompt: np.ndarray  # (S,) int32
    max_new_tokens: int = 16
    arrival_s: float = 0.0
    # filled by the engine:
    tokens_out: list = field(default_factory=list)
    t_first_token: float | None = None
    t_done: float | None = None

    @property
    def latency_s(self) -> float | None:
        return None if self.t_done is None else self.t_done - self.arrival_s


@dataclass(frozen=True)
class ServeConfig:
    slots: int = 4  # concurrent decode slots
    max_seq: int = 512  # cache capacity per slot
    greedy: bool = True


class Engine:
    """Single-model serving engine over the lm prefill/decode steps."""

    def __init__(self, cfg: ModelConfig, params: Any, sc: ServeConfig):
        self.cfg = cfg
        self.sc = sc
        self.params = params
        self._decode = jax.jit(
            lambda p, tok, pos, caches: lm.decode_step(p, cfg, tok, pos, caches)
        )
        self._prefill = jax.jit(
            lambda p, tokens: lm.prefill(p, cfg, tokens)
        )
        # slot state
        B, S = sc.slots, sc.max_seq
        self.caches = self._zero_caches(B, S)
        self.positions = np.zeros(B, np.int32)  # next position per slot
        self.active: list[Request | None] = [None] * B
        self.remaining = np.zeros(B, np.int32)
        self.queue: list[Request] = []
        self.completed: list[Request] = []
        self.service_log: list[tuple[float, float]] = []  # (t, service seconds)

    def _zero_caches(self, batch: int, seq: int):
        from repro.models.params import abstract_params, init_params
        from repro.models.lm import cache_template

        tpl = cache_template(self.cfg, batch, seq, enc_len=seq if self.cfg.is_encdec else 0)
        return init_params(tpl, jax.random.PRNGKey(0), jnp.dtype(self.cfg.dtype))

    # ------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        self.queue.append(req)

    def _admit(self, now: float) -> None:
        for slot in range(self.sc.slots):
            if self.active[slot] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            t0 = time.time()
            prompt = jnp.asarray(req.prompt[None], jnp.int32)
            logits, caches = self._prefill(self.params, prompt)
            next_tok = int(jnp.argmax(logits[0, -1]))
            # write this request's cache into the slot (batch index `slot`)
            self.caches = jax.tree.map(
                lambda full, one: self._write_slot(full, one, slot, len(req.prompt)),
                self.caches,
                caches,
            )
            self.positions[slot] = len(req.prompt)
            self.remaining[slot] = req.max_new_tokens - 1
            req.tokens_out.append(next_tok)
            req.t_first_token = now
            self.active[slot] = req
            self.service_log.append((now, time.time() - t0))

    @staticmethod
    def _write_slot(full, one, slot: int, prompt_len: int):
        """Place a single-request cache (leading batch 1) into slot `slot`.

        Sequence-bearing leaves (dim2 = cache capacity) copy the prompt
        prefix; state leaves (mamba/xLSTM) copy wholesale."""
        if full.ndim >= 3 and one.ndim == full.ndim and full.shape[2] != one.shape[2]:
            # kv-style cache: (n_sb, B, S_cap, ...) vs prefill (n_sb, 1, S_p, ...)
            s = min(one.shape[2], full.shape[2])
            return full.at[:, slot : slot + 1, :s].set(one[:, :, :s].astype(full.dtype))
        return full.at[:, slot : slot + 1].set(one.astype(full.dtype))

    # ------------------------------------------------------------------
    def tick(self, now: float | None = None) -> int:
        """Admit + one decode step for all active slots. Returns #active."""
        now = time.time() if now is None else now
        self._admit(now)
        if not any(r is not None for r in self.active):
            return 0
        t0 = time.time()
        last = np.zeros((self.sc.slots, 1), np.int32)
        for slot, req in enumerate(self.active):
            if req is not None:
                last[slot, 0] = req.tokens_out[-1]
        pos = int(max(self.positions[s] for s, r in enumerate(self.active) if r is not None))
        logits, self.caches = self._decode(
            self.params, jnp.asarray(last), jnp.int32(pos), self.caches
        )
        nxt = np.asarray(jnp.argmax(logits[:, 0], axis=-1))
        dt = time.time() - t0
        n_active = 0
        for slot, req in enumerate(self.active):
            if req is None:
                continue
            n_active += 1
            req.tokens_out.append(int(nxt[slot]))
            self.positions[slot] += 1
            self.remaining[slot] -= 1
            if self.remaining[slot] <= 0 or self.positions[slot] >= self.sc.max_seq - 1:
                req.t_done = now
                self.completed.append(req)
                self.active[slot] = None
        self.service_log.append((now, dt))
        return n_active

    def drain(self) -> None:
        while self.queue or any(r is not None for r in self.active):
            self.tick()

    # ------------------------------------------------------------------
    def observed_service_stats(self) -> tuple[float, float]:
        """(mean, var) of measured per-tick service times — the paper's
        profiled service-time input (§4.2)."""
        if not self.service_log:
            return 0.0, 0.0
        arr = np.array([s for _, s in self.service_log])
        return float(arr.mean()), float(arr.var())
