"""Workload generation for serving experiments (paper §4.1).

"We implement a workload generator that generates requests following a
Poisson process." Prompts/output lengths are drawn from configurable
distributions so the LLM case exhibits the variable service times the paper
models with M/M/1 (§3.5).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .engine import Request

__all__ = ["WorkloadConfig", "PoissonWorkload"]


@dataclass(frozen=True)
class WorkloadConfig:
    arrival_rate: float  # lambda (requests/s, simulated clock)
    prompt_len: int = 64
    prompt_len_jitter: int = 0  # uniform +/- jitter
    max_new_tokens: int = 16
    new_tokens_geometric_p: float = 0.0  # >0 -> geometric output lengths (LLM case)
    vocab: int = 256
    seed: int = 0


class PoissonWorkload:
    """Yields (arrival_time, Request) pairs on a simulated clock."""

    def __init__(self, wc: WorkloadConfig):
        self.wc = wc
        self.rng = np.random.default_rng(wc.seed)
        self._t = 0.0
        self._rid = 0

    def next_request(self) -> Request:
        wc = self.wc
        self._t += self.rng.exponential(1.0 / wc.arrival_rate)
        L = wc.prompt_len
        if wc.prompt_len_jitter:
            L += int(self.rng.integers(-wc.prompt_len_jitter, wc.prompt_len_jitter + 1))
        L = max(4, L)
        if wc.new_tokens_geometric_p > 0:
            nt = 1 + int(self.rng.geometric(wc.new_tokens_geometric_p))
            nt = min(nt, wc.max_new_tokens)
        else:
            nt = wc.max_new_tokens
        req = Request(
            rid=self._rid,
            prompt=self.rng.integers(0, wc.vocab, size=L).astype(np.int32),
            max_new_tokens=nt,
            arrival_s=self._t,
        )
        self._rid += 1
        return req

    def take(self, n: int) -> list[Request]:
        return [self.next_request() for _ in range(n)]
