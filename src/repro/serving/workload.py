"""Workload generation for serving experiments (paper §4.1).

"We implement a workload generator that generates requests following a
Poisson process." Prompts/output lengths are drawn from configurable
distributions so the LLM case exhibits the variable service times the paper
models with M/M/1 (§3.5).

The generator is deterministic per seed: the same ``WorkloadConfig`` yields
an identical request stream (arrival times, prompt tokens, lengths), which is
what makes ``repro.measure`` profiling runs replayable.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np

from .engine import Request

__all__ = ["WorkloadConfig", "PoissonWorkload"]

MIN_PROMPT_LEN = 4  # floor enforced on every sampled prompt length


@dataclass(frozen=True)
class WorkloadConfig:
    arrival_rate: float  # lambda (requests/s, simulated clock)
    prompt_len: int = 64
    prompt_len_jitter: int = 0  # uniform +/- jitter
    max_new_tokens: int = 16
    new_tokens_geometric_p: float = 0.0  # >0 -> geometric output lengths (LLM case)
    vocab: int = 256
    seed: int = 0

    def __post_init__(self):
        if not self.arrival_rate > 0:
            raise ValueError(f"arrival_rate must be > 0, got {self.arrival_rate}")
        if self.prompt_len_jitter < 0:
            raise ValueError(
                f"prompt_len_jitter must be >= 0, got {self.prompt_len_jitter}")
        if self.prompt_len - self.prompt_len_jitter < MIN_PROMPT_LEN:
            # the min-length floor would otherwise silently truncate the low
            # tail of the configured distribution (and jitter >= prompt_len
            # could even produce non-positive lengths)
            raise ValueError(
                "prompt_len - prompt_len_jitter must be >= "
                f"{MIN_PROMPT_LEN} so the minimum-length floor never clips "
                f"the configured distribution; got prompt_len={self.prompt_len}, "
                f"prompt_len_jitter={self.prompt_len_jitter}")
        if self.max_new_tokens < 1:
            raise ValueError(f"max_new_tokens must be >= 1, got {self.max_new_tokens}")
        if not 0.0 <= self.new_tokens_geometric_p < 1.0:
            raise ValueError(
                "new_tokens_geometric_p must be in [0, 1), got "
                f"{self.new_tokens_geometric_p}")
        if self.vocab < 1:
            raise ValueError(f"vocab must be >= 1, got {self.vocab}")

    @property
    def prompt_len_range(self) -> tuple[int, int]:
        """Inclusive (min, max) prompt length the generator can emit —
        exactly the shapes an engine warmup has to cover."""
        return (self.prompt_len - self.prompt_len_jitter,
                self.prompt_len + self.prompt_len_jitter)


class PoissonWorkload:
    """Yields (arrival_time, Request) pairs on a simulated clock."""

    def __init__(self, wc: WorkloadConfig):
        self.wc = wc
        self.rng = np.random.default_rng(wc.seed)
        self._t = 0.0
        self._rid = 0

    def next_request(self) -> Request:
        wc = self.wc
        self._t += self.rng.exponential(1.0 / wc.arrival_rate)
        L = wc.prompt_len
        if wc.prompt_len_jitter:
            L += int(self.rng.integers(-wc.prompt_len_jitter, wc.prompt_len_jitter + 1))
        assert L >= MIN_PROMPT_LEN  # guaranteed by WorkloadConfig validation
        if wc.new_tokens_geometric_p > 0:
            nt = 1 + int(self.rng.geometric(wc.new_tokens_geometric_p))
            nt = min(nt, wc.max_new_tokens)
        else:
            nt = wc.max_new_tokens
        req = Request(
            rid=self._rid,
            prompt=self.rng.integers(0, wc.vocab, size=L).astype(np.int32),
            max_new_tokens=nt,
            arrival_s=self._t,
        )
        self._rid += 1
        return req

    def take(self, n: int) -> list[Request]:
        return [self.next_request() for _ in range(n)]
