"""Bench regression gate: fresh ``BENCH_*.json`` vs committed baselines.

Each bench family's JSON artifact carries a few *headline* metrics — the
numbers a perf or model regression would move. This tool compares a freshly
produced artifact directory against the baselines committed under
``benchmarks/baselines/``, prints a delta table, and exits nonzero when any
headline regresses beyond its tolerance:

  * ``higher``-is-better metrics (throughputs) regress when
    ``fresh < baseline * (1 - tol)``;
  * ``lower``-is-better metrics (MAPE, iteration counts) regress when
    ``fresh > baseline * (1 + tol)``.

Improvements never fail the gate (refresh the baselines when they stick).

Absolute wall-clock throughputs (client-epochs/s, scenarios/s) are tagged
``machine_bound``: they are gated only under ``--machine-matched``, i.e. when
the fresh run and the baselines come from the same machine class — committed
baselines travel with the repo, CI runners don't match the machine that
recorded them, and a 2-3x hardware gap would otherwise fail every PR. In the
default (portable) mode they still appear in the delta table as ``info``
rows; the machine-insensitive headlines (speedups, MAPE, iteration counts,
model means) are always gated.

``--fresh`` accepts either a flat artifact directory (``benchmarks.run
--out``) or a ``results/`` tree produced by ``repro.launch.reproduce`` —
artifacts are found by name wherever they sit (``results/<exp-id>/<run-id>/
seed-<s>/BENCH_*.json``); with several runs of one family the newest wins.

Usage:
  python -m benchmarks.check_regression --fresh artifacts
  python -m benchmarks.check_regression --fresh results
  python -m benchmarks.check_regression --fresh artifacts --machine-matched
  python -m benchmarks.check_regression --fresh artifacts --update-baselines
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

DEFAULT_TOLERANCE = 0.30

# family artifact -> {dotted metric path: (direction, tolerance or None,
# machine_bound)}. tolerance None = the run's default; machine_bound metrics
# (absolute wall-clock rates) gate only under --machine-matched. The 45%
# machine-matched tolerance still trips on a synthetic 2x slowdown.
HEADLINES: dict[str, dict[str, tuple[str, float | None, bool]]] = {
    "BENCH_fleet.json": {
        "analytic.vec_scenarios_per_sec": ("higher", 0.45, True),
        "analytic.speedup": ("higher", None, False),
        "crossover.speedup": ("higher", None, False),
        "simulation.vec_jobs_per_sec": ("higher", 0.45, True),
        "simulation.vec_vs_scalar_mean_gap": ("lower", None, False),
    },
    "BENCH_cluster.json": {
        "closed_loop.client_epochs_per_sec": ("higher", 0.45, True),
        "closed_loop.adaptive_mean_latency_s": ("lower", None, False),
        "equilibrium.iterations": ("lower", None, False),
    },
    "BENCH_validate.json": {
        "smoke_gate_mean_mape_pct": ("lower", None, False),
    },
    "BENCH_meanfield.json": {
        "diurnal.client_epochs_per_sec": ("higher", 0.45, True),
        # deterministic model headlines: the diurnal day's fleet mean and the
        # fixed-point iteration count must not creep; any saturated
        # class-epoch at all is a model drift (the day is sized stable)
        "diurnal.mean_latency_s": ("lower", None, False),
        "diurnal.saturated_epochs": ("lower", 0.0, False),
        "equilibrium.iterations": ("lower", None, False),
        "cross_check.gated_max_mape_pct": ("lower", None, False),
    },
    "BENCH_tail.json": {
        "vec_euler_rows_per_sec": ("higher", 0.45, True),
        "euler_vec_rows_per_s": ("higher", 0.45, True),
        # acceptance rides on <= 10x; both sides timed in the same run, so
        # the ratio is machine-insensitive and gated portably
        "euler_vec_slowdown_vs_asym": ("lower", None, False),
        # ~1e-11 in practice (identical scalar/vec trajectories); 9.0 trips
        # on an order-of-magnitude error growth without float-jitter flakes
        "euler_vec_vs_scalar_max_err": ("lower", 9.0, False),
        "asym_vs_euler_p99_mean_gap_pct": ("lower", None, False),
        "station_pass_speedup": ("higher", None, False),
    },
    "BENCH_paper_figures.json": {
        "fig2_mape_pct": ("lower", None, False),
        "fig3_mape_pct": ("lower", None, False),
    },
    "BENCH_measure.json": {
        "engine.tokens_per_sec": ("higher", 0.45, True),
        "harness.requests_per_sec": ("higher", 0.45, True),
        "fit.wall_ms": ("lower", 0.45, True),
        # seeded simulated clock -> deterministic MAPE: gated portably
        "gate.mean_mape_pct": ("lower", None, False),
        "gate.p99_mape_pct": ("lower", None, False),
    },
    "BENCH_obs.json": {
        # pass-flags (1.0 = pass) gated at zero tolerance: observability must
        # stay free when disabled (<=5% engine overhead) and every audited
        # decision's terms must re-sum to its totals within 1e-9
        "tracer.overhead_gate_pass": ("higher", 0.0, False),
        "audit.resum_gate_pass": ("higher", 0.0, False),
        "tracer.tokens_per_sec_enabled": ("higher", 0.45, True),
        "audit.rows_per_sec": ("higher", 0.45, True),
    },
    "BENCH_plan.json": {
        "solver.wall_s": ("lower", 0.45, True),
        # deterministic search-cost and model-output headlines: more
        # equilibrium solves or a bigger minimal fleet = solver or model drift
        "solver.evaluations": ("lower", 0.0, False),
        "plan.n_edges": ("lower", 0.0, False),
        "plan.max_latency_ms": ("lower", None, False),
    },
    # interpret-mode numerics vs reference; 9.0 = an order-of-magnitude error
    # growth trips the gate without flaking on cross-platform BLAS jitter
    "BENCH_kernels.json": {
        "flash_attention.max_abs_err": ("lower", 9.0, False),
        "decode_attention.max_abs_err": ("lower", 9.0, False),
        "ssm_scan.max_abs_err": ("lower", 9.0, False),
        "rmsnorm.max_abs_err": ("lower", 9.0, False),
        "lindley_scan.max_abs_err": ("lower", 9.0, False),
        # integer choice trajectories: any mismatch at all is a drift
        "decision_scan.max_abs_err": ("lower", 0.0, False),
    },
}


def resolve(doc: dict, path: str):
    """Dotted-path lookup; None when any segment is missing."""
    cur = doc
    for part in path.split("."):
        if not isinstance(cur, dict) or part not in cur:
            return None
        cur = cur[part]
    return cur


def default_baseline_dir() -> Path:
    return Path(__file__).resolve().parent / "baselines"


def resolve_artifact(root: Path, fname: str) -> Path | None:
    """Locate a family artifact under ``root``: the flat layout first, then
    anywhere in a nested ``results/`` tree (newest mtime wins when a family
    appears in several runs). None when absent entirely."""
    direct = root / fname
    if direct.exists():
        return direct
    nested = [p for p in root.rglob(fname) if p.is_file()]
    if not nested:
        return None
    return max(nested, key=lambda p: p.stat().st_mtime)


def compare(
    fresh_dir: Path,
    baseline_dir: Path,
    *,
    tolerance: float = DEFAULT_TOLERANCE,
    machine_matched: bool = False,
    families: list[str] | None = None,
) -> tuple[list[dict], int]:
    """(rows, n_regressions) over every headline family.

    Missing data is loud on BOTH sides: a family with a committed baseline
    but no fresh artifact (a renamed file, a family dropped from the CI
    ``--only`` list), a family produced fresh with no baseline, and a metric
    absent from either side all count as regressions — silent shrinkage of
    the gate is exactly what this tool exists to catch. Only a family absent
    from both directories is skipped (not part of this comparison at all; a
    deliberate partial run should point ``--fresh`` at a directory holding
    just the families it wants compared AND baselined, or restrict the
    comparison with ``families``). ``machine_matched`` additionally gates the
    machine-bound (absolute wall-clock) headlines; otherwise those are
    informational rows. ``families`` restricts the comparison to those
    artifact filenames (for declared partial runs, e.g. ``reproduce
    --only``); None compares every headline family."""
    rows: list[dict] = []
    regressions = 0
    for fname, metrics in sorted(HEADLINES.items()):
        if families is not None and fname not in families:
            continue
        fresh_path = resolve_artifact(fresh_dir, fname)
        base_path = resolve_artifact(baseline_dir, fname)
        if fresh_path is None and base_path is None:
            continue
        fresh = json.loads(fresh_path.read_text()) if fresh_path else {}
        base = json.loads(base_path.read_text()) if base_path else {}
        for metric, (direction, tol, machine_bound) in metrics.items():
            tol = tolerance if tol is None else tol
            gated = machine_matched or not machine_bound
            f_val = resolve(fresh, metric)
            b_val = resolve(base, metric)
            if f_val is None or b_val is None:
                rows.append({
                    "family": fname, "metric": metric, "baseline": b_val,
                    "fresh": f_val, "delta_pct": None, "tol_pct": tol * 100,
                    "status": "MISSING",
                })
                regressions += 1
                continue
            f_val, b_val = float(f_val), float(b_val)
            delta = (f_val - b_val) / b_val * 100.0 if b_val != 0 else float("inf")
            if direction == "higher":
                bad = f_val < b_val * (1.0 - tol)
            else:
                bad = f_val > b_val * (1.0 + tol)
            if bad and gated:
                regressions += 1
                status = "REGRESSED"
            elif not gated:
                status = "info(slower)" if bad else "info"
            else:
                status = "ok"
            rows.append({
                "family": fname, "metric": metric, "baseline": b_val,
                "fresh": f_val, "delta_pct": delta, "tol_pct": tol * 100,
                "status": status,
            })
    return rows, regressions


def manifest_notes(fresh_dir: Path, baseline_dir: Path,
                   families: list[str] | None = None) -> list[str]:
    """Informational provenance-drift notes: for every compared family whose
    fresh artifact AND baseline both carry a ``manifest`` block, report what
    differs (git sha, package versions, platform). Purely informational —
    the gates above fire regardless; this just says when a delta may be
    explained by baselines recorded under different provenance."""
    try:
        from repro.obs import manifest_delta
    except ImportError:  # benchmarks runnable without repro on the path
        return []
    notes: list[str] = []
    for fname in sorted(HEADLINES):
        if families is not None and fname not in families:
            continue
        fresh_path = resolve_artifact(fresh_dir, fname)
        base_path = resolve_artifact(baseline_dir, fname)
        if fresh_path is None or base_path is None:
            continue
        try:
            fm = json.loads(fresh_path.read_text()).get("manifest")
            bm = json.loads(base_path.read_text()).get("manifest")
        except (OSError, json.JSONDecodeError):
            continue
        for delta in manifest_delta(bm, fm):
            notes.append(f"{fname}: {delta}")
    return notes


def print_table(rows: list[dict]) -> None:
    if not rows:
        print("no comparable BENCH_*.json families found")
        return
    print(f"{'family':26s} {'metric':42s} {'baseline':>12s} {'fresh':>12s} "
          f"{'delta':>8s} {'tol':>6s}  status")
    for r in rows:
        base = "-" if r["baseline"] is None else f"{r['baseline']:.4g}"
        fresh = "-" if r["fresh"] is None else f"{r['fresh']:.4g}"
        delta = "-" if r["delta_pct"] is None else f"{r['delta_pct']:+.1f}%"
        print(f"{r['family']:26s} {r['metric']:42s} {base:>12s} {fresh:>12s} "
              f"{delta:>8s} {r['tol_pct']:5.0f}%  {r['status']}")


# manifest fields that survive into a committed baseline: portable run
# identity only. git sha, python/platform, and package versions are bound to
# the machine that recorded the baseline and would otherwise emit perpetual
# informational drift notes on every foreign rerun.
_PORTABLE_MANIFEST_KEYS = ("manifest_version", "seed", "config_sha256")


def _strip_manifest(doc: dict) -> dict:
    m = doc.get("manifest")
    if isinstance(m, dict):
        doc = dict(doc)
        doc["manifest"] = {k: m[k] for k in _PORTABLE_MANIFEST_KEYS if k in m}
    return doc


def update_baselines(fresh_dir: Path, baseline_dir: Path) -> list[str]:
    """Copy every known family artifact from ``fresh_dir`` into the baseline
    directory (whole files, so future headline additions have data), with the
    machine/git-bound manifest fields stripped down to
    ``_PORTABLE_MANIFEST_KEYS`` — committed baselines travel with the repo
    and must not pin the provenance of whoever last refreshed them."""
    baseline_dir.mkdir(parents=True, exist_ok=True)
    copied = []
    for fname in HEADLINES:
        src = resolve_artifact(fresh_dir, fname)
        if src is not None:
            doc = _strip_manifest(json.loads(src.read_text()))
            (baseline_dir / fname).write_text(json.dumps(doc, indent=2) + "\n")
            copied.append(fname)
    return copied


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__,
                                 formatter_class=argparse.RawDescriptionHelpFormatter)
    ap.add_argument("--fresh", type=Path, default=Path("artifacts"),
                    help="directory with freshly produced BENCH_*.json")
    ap.add_argument("--baselines", type=Path, default=default_baseline_dir(),
                    help="committed baseline directory (default benchmarks/baselines)")
    ap.add_argument("--tolerance", type=float, default=DEFAULT_TOLERANCE,
                    help="default relative tolerance (default 0.30 = ±30%%); "
                         "per-metric overrides in HEADLINES still apply")
    ap.add_argument("--machine-matched", action="store_true",
                    help="also gate the absolute wall-clock throughputs (use "
                         "when baselines were recorded on this machine class)")
    ap.add_argument("--update-baselines", action="store_true",
                    help="replace the baselines with the fresh artifacts and exit")
    args = ap.parse_args(argv)

    if not args.fresh.is_dir():
        print(f"error: fresh artifact directory {args.fresh} does not exist",
              file=sys.stderr)
        return 2
    if args.update_baselines:
        copied = update_baselines(args.fresh, args.baselines)
        if not copied:
            print(f"error: no known BENCH_*.json in {args.fresh}", file=sys.stderr)
            return 2
        print(f"updated baselines: {', '.join(copied)} -> {args.baselines}")
        return 0

    rows, regressions = compare(args.fresh, args.baselines,
                                tolerance=args.tolerance,
                                machine_matched=args.machine_matched)
    print_table(rows)
    notes = manifest_notes(args.fresh, args.baselines)
    if notes:
        print("\nbaseline provenance differs from this run (informational):")
        for note in notes:
            print(f"  {note}")
    if not rows:
        print("error: nothing compared — wrong --fresh directory?", file=sys.stderr)
        return 2
    if regressions:
        print(f"\n{regressions} headline metric(s) regressed beyond tolerance",
              file=sys.stderr)
        return 1
    print(f"\nall {len(rows)} headline metrics within tolerance")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
