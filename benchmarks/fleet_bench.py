"""Fleet-engine benchmark: scalar vs vectorized scenario evaluation.

Times the three fleet paths against their scalar `repro.core` counterparts on
the same specs and emits CSV rows plus a ``BENCH_fleet.json`` artifact:

  * ``fleet_analytic`` over a 131072-scenario cartesian grid vs a scalar
    ``scenario.analytic()`` loop (per-scenario cost extrapolated from a
    subsample — the scalar loop over the full grid would take minutes);
  * ``fleet_crossover`` batched bisection vs scalar ``crossovers()``;
  * ``simulate_fleet`` batched Lindley scan vs scalar ``simulate()``
    (jobs/second, identical tandem spec).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core.latency import NetworkPath, Tier, Workload
from repro.core.scenario import EdgeSpec, Scenario, analytic, crossovers, simulate
from repro.fleet import ScenarioBatch, fleet_analytic, fleet_crossover, simulate_fleet

from .common import emit

GRID_BW = 512
GRID_LAM = 256
SCALAR_SAMPLE = 256
SIM_BATCH = 256
SIM_JOBS = 4_096
CX_BATCH = 4_096
CX_SCALAR = 32


def _base() -> Scenario:
    return Scenario(
        workload=Workload(2.0, 30_000, 1_000, name="inceptionv4"),
        device=Tier("tx2", 0.150),
        edges=(EdgeSpec(Tier("a2", 0.028)),),
        network=NetworkPath(5e6 / 8),
        allow_unstable=True,  # the grid deliberately crosses saturation
        name="fleet-bench",
    )


def fleet_rows(out_dir: Path | None = None) -> dict:
    base = _base()
    axes = {
        "network.bandwidth_Bps": np.geomspace(1e5, 1e8, GRID_BW),
        "workload.arrival_rate": np.linspace(0.5, 30.0, GRID_LAM),
    }

    # -- analytic: vectorized full grid ---------------------------------------
    t0 = time.perf_counter()
    batch = ScenarioBatch.from_sweep(base, axes)
    pack_s = time.perf_counter() - t0
    fleet_analytic(batch)  # warm/compile
    t0 = time.perf_counter()
    for _ in range(3):
        fleet_analytic(batch)
    vec_s = (time.perf_counter() - t0) / 3
    vec_rate = batch.size / vec_s

    # -- analytic: scalar loop on a subsample, extrapolated --------------------
    rng = np.random.default_rng(0)
    bw_idx = rng.integers(0, GRID_BW, SCALAR_SAMPLE)
    lam_idx = rng.integers(0, GRID_LAM, SCALAR_SAMPLE)
    sample = [
        base.replaced("network.bandwidth_Bps", float(axes["network.bandwidth_Bps"][i]))
        .replaced("workload.arrival_rate", float(axes["workload.arrival_rate"][j]))
        for i, j in zip(bw_idx, lam_idx)
    ]
    t0 = time.perf_counter()
    for scn in sample:
        analytic(scn)
    scalar_s = (time.perf_counter() - t0) / SCALAR_SAMPLE
    scalar_rate = 1.0 / scalar_s
    emit("fleet_analytic_vec", vec_s / batch.size * 1e6,
         f"scenarios_per_sec={vec_rate:.3e};batch={batch.size};pack_ms={pack_s*1e3:.1f}")
    emit("fleet_analytic_scalar", scalar_s * 1e6,
         f"scenarios_per_sec={scalar_rate:.3e};speedup_vec={vec_rate/scalar_rate:.1f}x")

    # -- crossover: batched bisection vs scalar solver -------------------------
    cx_axes = {"workload.arrival_rate": np.linspace(0.5, 30.0, CX_BATCH)}
    cx_batch = ScenarioBatch.from_sweep(base, cx_axes)
    fleet_crossover(cx_batch, "bandwidth")  # warm/compile
    t0 = time.perf_counter()
    cx = fleet_crossover(cx_batch, "bandwidth")
    cx_vec_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    for scn in base.sweep("workload.arrival_rate", np.linspace(0.5, 30.0, CX_SCALAR)):
        crossovers(scn, "bandwidth")
    cx_scalar_s = (time.perf_counter() - t0) / CX_SCALAR
    cx_vec_rate = cx_batch.size / cx_vec_s
    cx_scalar_rate = 1.0 / cx_scalar_s
    emit("fleet_crossover_vec", cx_vec_s / cx_batch.size * 1e6,
         f"crossovers_per_sec={cx_vec_rate:.3e};found_frac={cx.found.mean():.3f}")
    emit("fleet_crossover_scalar", cx_scalar_s * 1e6,
         f"crossovers_per_sec={cx_scalar_rate:.3e};speedup_vec={cx_vec_rate/cx_scalar_rate:.1f}x")

    # -- simulation: batched Lindley scan vs scalar tandem ---------------------
    sim_batch = ScenarioBatch.from_scenarios([base] * SIM_BATCH)
    simulate_fleet(sim_batch, "edge[0]", n=SIM_JOBS, seed=0)  # warm/compile
    t0 = time.perf_counter()
    res = simulate_fleet(sim_batch, "edge[0]", n=SIM_JOBS, seed=1)
    sim_vec_s = time.perf_counter() - t0
    t0 = time.perf_counter()
    scalar_sim = simulate(base, "edge[0]", n=SIM_JOBS, seed=1)
    sim_scalar_s = time.perf_counter() - t0
    vec_jobs = SIM_BATCH * SIM_JOBS / sim_vec_s
    scalar_jobs = SIM_JOBS / sim_scalar_s
    sim_gap = abs(float(np.mean(res.mean)) - scalar_sim.mean) / scalar_sim.mean
    emit("fleet_sim_vec", sim_vec_s / SIM_BATCH * 1e6,
         f"jobs_per_sec={vec_jobs:.3e};batch={SIM_BATCH}x{SIM_JOBS}")
    emit("fleet_sim_scalar", sim_scalar_s * 1e6,
         f"jobs_per_sec={scalar_jobs:.3e};speedup_vec={vec_jobs/scalar_jobs:.1f}x;mean_gap={sim_gap:.3f}")

    report = {
        "analytic": {
            "batch": batch.size,
            "pack_ms": pack_s * 1e3,
            "vec_scenarios_per_sec": vec_rate,
            "scalar_scenarios_per_sec": scalar_rate,
            "speedup": vec_rate / scalar_rate,
        },
        "crossover": {
            "batch": cx_batch.size,
            "vec_crossovers_per_sec": cx_vec_rate,
            "scalar_crossovers_per_sec": cx_scalar_rate,
            "speedup": cx_vec_rate / cx_scalar_rate,
            "found_frac": float(cx.found.mean()),
        },
        "simulation": {
            "batch": SIM_BATCH,
            "jobs_per_scenario": SIM_JOBS,
            "vec_jobs_per_sec": vec_jobs,
            "scalar_jobs_per_sec": scalar_jobs,
            "speedup": vec_jobs / scalar_jobs,
            "vec_vs_scalar_mean_gap": sim_gap,
        },
    }
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / "BENCH_fleet.json").write_text(json.dumps(report, indent=2))
    return report


if __name__ == "__main__":
    fleet_rows(Path("experiments/bench"))
