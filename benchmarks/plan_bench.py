"""Provisioning-solver benchmark: what does inverting the fleet model cost?

Runs the README's worked fleet-sizing example (N clients, p99 budget, a
3-tier accelerator ladder x 8 edges x 4 bandwidths) through
``repro.plan.provision`` with exact euler tails and records both the cost
(wall time, equilibrium solves spent) and the *answer* (edges/tier/bandwidth
picked, worst-client p99) — so a solver perf regression and a model-output
drift both land in the same row history. ``evaluations`` vs the exhaustive
grid size is the headline: the per-axis bisection should stay logarithmic.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from repro.launch.provision import default_space
from repro.plan import provision

from .common import emit

N_CLIENTS = 48
SLO_S = 0.120
Q = 0.99


def plan_rows(out_dir: Path | None = None) -> dict:
    space = default_space()
    grid = space.max_edges * len(space.tiers) * len(space.bandwidths_Bps)

    t0 = time.perf_counter()
    plan = provision(space, N_CLIENTS, SLO_S, q=Q, tail_method="euler")
    wall_s = time.perf_counter() - t0
    assert plan is not None, "bench space must be feasible"

    emit("plan_provision_48c", wall_s * 1e6,
         f"{plan.evaluations}_of_{grid}_grid_solves")
    emit("plan_provision_result", 0.0,
         f"{plan.n_edges}x_{plan.tier.name}_{plan.bandwidth_Bps * 8 / 1e6:.0f}Mbit")
    emit("plan_provision_p99", 0.0,
         f"{plan.max_latency_s * 1e3:.1f}ms_budget_{SLO_S * 1e3:.0f}ms")

    report = {
        "n_clients": N_CLIENTS,
        "slo_ms": SLO_S * 1e3,
        "q": Q,
        "grid_size": grid,
        "solver": {
            "wall_s": wall_s,
            "evaluations": plan.evaluations,
            "grid_over_evals": grid / plan.evaluations,
        },
        "plan": {
            "n_edges": plan.n_edges,
            "tier": plan.tier.name,
            "tier_index": plan.tier_index,
            "bandwidth_Mbit": plan.bandwidth_Bps * 8 / 1e6,
            "max_latency_ms": plan.max_latency_s * 1e3,
            "mean_latency_ms": plan.mean_latency_s * 1e3,
        },
    }
    if out_dir is not None:
        (Path(out_dir) / "BENCH_plan.json").write_text(json.dumps(report, indent=2))
    return report


if __name__ == "__main__":
    plan_rows(Path("."))
