"""Tail-latency layer benchmark: what do sojourn quantiles cost, and how far
apart are the two methods?

Times the scalar Abate-Whitt path (``Scenario.analytic_tail``) over the full
golden corpus, the jitted batch quantiles (``fleet_tail``, both methods) over
a bandwidth x arrival-rate sweep, and the vectorized-vs-loop ``station_pass``
k=1 speedup the validate gate rides on. ``derived`` carries the model
headline next to each perf number — the asymptote-vs-Euler p99 gap and the
p99-vs-mean crossover shift — so a perf regression AND a model regression
both show up in the same row history.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import NetworkPath, Scenario, Tier, Workload
from repro.core.latency import ServiceModel
from repro.core.scenario import EdgeSpec, analytic_tail
from repro.core.simulation import _station_pass_k1_loop, station_pass
from repro.fleet import ScenarioBatch, fleet_tail
from repro.validate import generate_corpus

from .common import emit, timed

Q = 0.99
SWEEP_B = 64  # bandwidth points
SWEEP_LAM = 32  # arrival-rate points


def _example_scenario() -> Scenario:
    return Scenario(
        workload=Workload(8.0, 50_000, 4_000),
        device=Tier("dev", 0.05, service_model=ServiceModel.DETERMINISTIC),
        network=NetworkPath(2.5e6),
        edges=(EdgeSpec(Tier("edge", 0.018, service_model=ServiceModel.EXPONENTIAL)),),
    )


def tail_rows(out_dir: Path | None = None) -> dict:
    entries = generate_corpus(0)
    scns = [e.scenario for e in entries]

    # -- scalar Euler quantiles over the full corpus --------------------------
    t0 = time.perf_counter()
    scalar_tails = [analytic_tail(s, Q) for s in scns]
    us_scalar = (time.perf_counter() - t0) * 1e6
    emit("tail_scalar_p99_corpus", us_scalar, f"{len(scns)}_scenarios")

    # -- batched quantiles over a 2-axis sweep --------------------------------
    base = _example_scenario()
    batch = ScenarioBatch.from_sweep(base, {
        "network.bandwidth_Bps": np.geomspace(2.5e5, 2.5e7, SWEEP_B),
        "workload.arrival_rate": np.linspace(1.0, 16.0, SWEEP_LAM),
    })
    rows = batch.size
    _, us_euler = timed(fleet_tail, batch, Q, method="euler")
    _, us_asym = timed(fleet_tail, batch, Q, method="asymptote")
    euler_rps = rows / (us_euler / 1e6)
    asym_rps = rows / (us_asym / 1e6)
    slowdown = asym_rps / euler_rps
    emit("tail_vec_euler", us_euler, f"{euler_rps:.0f}_rows_per_s")
    emit("tail_vec_asymptote", us_asym, f"{asym_rps:.0f}_rows_per_s")
    emit("tail_euler_vs_asym_slowdown", 0.0, f"{slowdown:.2f}x_acceptance_le_10x")

    # -- batched exact euler vs scalar euler over the corpus ------------------
    # the differential harness gates this at 1e-8 per entry; the bench tracks
    # the actual ceiling (~1e-11: both sides run the identical trajectory)
    cbatch = ScenarioBatch.from_scenarios(scns)
    cpred = fleet_tail(cbatch, Q, method="euler")
    errs = []
    for i, te in enumerate(scalar_tails):
        vt = cpred.totals(i)
        for k, v in te.items():
            if np.isfinite(v) and np.isfinite(vt[k]):
                errs.append(abs(v - vt[k]) / max(abs(v), abs(vt[k]), 1e-300))
            elif np.isfinite(v) != np.isfinite(vt[k]):
                errs.append(float("inf"))
    euler_vec_err = float(np.max(errs))
    emit("tail_euler_vec_vs_scalar", 0.0, f"{euler_vec_err:.1e}_max_rel_err")

    # -- asymptote-vs-Euler p99 gap over the corpus (model headline) ----------
    gaps = []
    for s, te in zip(scns, scalar_tails):
        ta = analytic_tail(s, Q, method="asymptote")
        for k, v in te.items():
            if np.isfinite(v) and np.isfinite(ta[k]) and v > 0:
                gaps.append(abs(ta[k] - v) / v * 100.0)
    gap_pct = float(np.mean(gaps))
    emit("tail_asym_vs_euler_gap", 0.0, f"{gap_pct:.2f}pct_mean_p99_gap")

    # -- p99 vs mean crossover shift (the new result class) -------------------
    cm = base.crossovers("bandwidth")
    cq = base.crossovers("bandwidth", quantile=Q)
    ratio = float(cq.value / cm.value)
    emit("tail_p99_crossover_shift", 0.0, f"{ratio:.3f}x_mean_crossover")

    # -- vectorized station_pass k=1 vs the old Python loop -------------------
    rng = np.random.default_rng(0)
    n = 100_000
    arr = np.cumsum(rng.exponential(0.1, size=n))
    svc = rng.exponential(0.08, size=n)
    _, us_loop = timed(_station_pass_k1_loop, arr, svc)
    _, us_vec = timed(station_pass, arr, svc, 1)
    speedup = us_loop / us_vec
    emit("tail_station_pass_k1_100k", us_vec, f"{speedup:.0f}x_vs_loop")

    report = {
        "corpus_entries": len(scns),
        "q": Q,
        "scalar_us_per_scenario": us_scalar / len(scns),
        "sweep_rows": rows,
        "vec_euler_rows_per_sec": euler_rps,
        "euler_vec_rows_per_s": euler_rps,
        "vec_asym_rows_per_sec": asym_rps,
        "euler_vec_slowdown_vs_asym": float(slowdown),
        "euler_vec_vs_scalar_max_err": euler_vec_err,
        "asym_vs_euler_p99_mean_gap_pct": gap_pct,
        "p99_over_mean_crossover_ratio": ratio,
        "station_pass_speedup": float(speedup),
    }
    if out_dir is not None:
        (Path(out_dir) / "BENCH_tail.json").write_text(json.dumps(report, indent=2))
    return report


if __name__ == "__main__":
    tail_rows(Path("."))
