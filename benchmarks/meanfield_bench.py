"""Mean-field fleet benchmark: a million-client diurnal day in seconds.

The point of `repro.fleet.meanfield` is that closed-loop cost is O(C * E^2)
per epoch — independent of N — so a fleet three orders of magnitude past the
exact simulator's reach prices a full day on one CPU host. This bench pins
that claim and emits ``BENCH_meanfield.json``:

  * ``meanfield_day`` — a 1,000,000-client, 4-class, 4-edge fleet through a
    1440-epoch diurnal day (daytime bandwidth squeeze + MMPP flash-crowd
    churn on the arrival and exogenous-load sides). Headline:
    client-epochs/s (machine-bound) — the acceptance criterion is the whole
    day end-to-end in minutes, and warm it runs in seconds;
  * ``meanfield_equilibrium`` — the damped Wardrop fixed point on the same
    million-client spec (headline: iterations to converge, a model-behaviour
    metric that must not creep);
  * ``meanfield_cross_check`` — the mean-field-vs-exact agreement on the
    validation harness's fixed small fleet (headline: gated max MAPE, the
    portable model-fidelity number).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.core import (
    ClientClass,
    EdgeSpec,
    MeanFieldSpec,
    NetworkPath,
    Scenario,
    ServiceModel,
    Tier,
    Workload,
)
from repro.fleet import (
    TraceBatch,
    cross_check_meanfield,
    mmpp_signal,
    simulate_meanfield,
    solve_meanfield_equilibrium,
    step_signal,
)
from repro.validate import meanfield_gate_specs

from .common import emit

N_CLIENTS = 1_000_000
EPOCHS = 1_440  # one day at 60 s epochs
EPOCH_S = 60.0
DAY_S = EPOCHS * EPOCH_S
BW0_BPS = 2.5e6  # 20 Mbit shared path
BW_DAYTIME = 0.4  # daytime congestion squeezes the uplink to 40%


def meanfield_day_spec() -> MeanFieldSpec:
    """The million-client fleet: four bandwidth/rate classes over four
    pooled accelerator tiers sized so the aggregate ~55 krps fleet keeps
    every edge inside the stable region at full bandwidth.

    Results are not returned: the model prices the return path as one queue
    at the edge's AGGREGATE rate over the client's bandwidth (the paper's
    single-path serialization), which caps any edge at bw/res_bytes — a few
    krps — regardless of accelerator pool size. Fire-and-forget is the
    regime where pooling to this scale is meaningful."""
    base = Scenario(
        workload=Workload(arrival_rate=0.05, req_bytes=30_000, res_bytes=0,
                          name="mf-bench"),
        device=Tier("orin", 0.045),
        network=NetworkPath(BW0_BPS),
        edges=(
            EdgeSpec(Tier("a100", 0.008, parallelism_k=1024.0)),
            EdgeSpec(Tier("a2", 0.028, parallelism_k=2048.0)),
            EdgeSpec(Tier("t4", 0.020, parallelism_k=2048.0,
                          service_model=ServiceModel.EXPONENTIAL)),
            EdgeSpec(Tier("mixed", 0.015, parallelism_k=1024.0,
                          service_model=ServiceModel.GENERAL,
                          service_var=0.25 * 0.015 * 0.015)),
        ),
        name="mf-bench-base",
    )
    classes = (
        ClientClass(n_clients=400_000, arrival_scale=1.0, name="steady"),
        ClientClass(n_clients=300_000, arrival_scale=0.5, name="light"),
        ClientClass(n_clients=200_000, arrival_scale=2.0, bandwidth_scale=0.5,
                    name="heavy"),
        ClientClass(n_clients=100_000, arrival_scale=1.5, bandwidth_scale=0.25,
                    name="cellular"),
    )
    return MeanFieldSpec(base=base, classes=classes, name="mf-million")


def diurnal_traces(spec: MeanFieldSpec) -> TraceBatch:
    """Per-class day: a daytime bandwidth squeeze for everyone, MMPP burst
    churn on the heavy class's arrival rate, and an MMPP flash crowd of
    exogenous load on the fastest edge."""
    times = np.arange(0.0, DAY_S, EPOCH_S)
    squeeze = step_signal(times, [(0.0, 1.0), (DAY_S / 3, BW_DAYTIME),
                                  (2 * DAY_S / 3, 1.0)])
    bw0 = spec.bandwidth_Bps()  # (C,) class scales folded in
    bw = bw0[None, :] * squeeze[:, None]
    lam = np.broadcast_to(spec.arrival_rates(),
                          (len(times), spec.n_classes)).copy()
    heavy = [c.name for c in spec.classes].index("heavy")
    lam[:, heavy] *= mmpp_signal(times, 1.0, 1.5, p_up=0.05, p_down=0.2,
                                 seed=11)
    exo = np.zeros((len(times), spec.n_edges))
    exo[:, 0] = mmpp_signal(times, 0.0, 20_000.0, p_up=0.03, p_down=0.25,
                            seed=13)
    return TraceBatch(times=times, bandwidth_Bps=bw, arrival_rate=lam,
                      edge_bg_rate=exo)


def meanfield_rows(out_dir: Path | None = None) -> dict:
    spec = meanfield_day_spec()
    traces = diurnal_traces(spec)

    # full day once to compile, then a warm pass for the throughput headline
    res = simulate_meanfield(spec, traces)
    t0 = time.perf_counter()
    res = simulate_meanfield(spec, traces)
    day_s = time.perf_counter() - t0
    rate = res.client_epochs / day_s
    off = res.offload_frac
    emit("meanfield_day", day_s / res.n_epochs * 1e6,
         f"client_epochs_per_sec={rate:.3e};clients={spec.n_total};"
         f"epochs={res.n_epochs}")

    solve_meanfield_equilibrium(spec)  # warm
    t0 = time.perf_counter()
    mf = solve_meanfield_equilibrium(spec)
    eq_s = time.perf_counter() - t0
    emit("meanfield_equilibrium", eq_s * 1e6,
         f"iterations={mf.iterations};converged={mf.converged};"
         f"offload_frac={mf.offload_frac:.3f}")

    t0 = time.perf_counter()
    check = cross_check_meanfield(meanfield_gate_specs()[0])
    check_s = time.perf_counter() - t0
    emit("meanfield_cross_check", check_s * 1e6,
         f"gated_max_mape_pct={check['gated_max_mape_pct']:.3f}")

    report = {
        "diurnal": {
            "n_clients": spec.n_total,
            "classes": spec.n_classes,
            "edges": spec.n_edges,
            "epochs": res.n_epochs,
            "epoch_s": EPOCH_S,
            "client_epochs": res.client_epochs,
            "wall_s": day_s,
            "client_epochs_per_sec": rate,
            "mean_latency_s": res.mean_latency_s,
            "offload_frac_min": float(off.min()),
            "offload_frac_max": float(off.max()),
            "saturated_epochs": res.saturated_epochs,
            "peak_rho_edges": res.rho_edges.max(axis=0).tolist(),
        },
        "equilibrium": {
            "iterations": mf.iterations,
            "converged": mf.converged,
            "regret_pct": mf.regret_pct,
            "solve_ms": eq_s * 1e3,
            "mean_latency_s": mf.mean_latency_s,
            "offload_frac": mf.offload_frac,
            "rho_edges": mf.rho_edges.tolist(),
        },
        "cross_check": {
            "spec": meanfield_gate_specs()[0].name,
            "wall_ms": check_s * 1e3,
            "gated_max_mape_pct": check["gated_max_mape_pct"],
            "gated_mean_mape_pct": check["gated_mean_mape_pct"],
            "converged": bool(check["meanfield_converged"]
                              and check["exact_converged"]),
        },
    }
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / "BENCH_meanfield.json").write_text(json.dumps(report, indent=2))
    return report


if __name__ == "__main__":
    meanfield_rows(Path("experiments/bench"))
