"""Kernel rows for the benchmark CSV + the ``BENCH_kernels.json`` artifact:
reference-path timing + validated max-abs error of the Pallas kernel
(interpret mode) at a representative shape.

``max_abs_err`` values are headline-gated by ``check_regression`` (a 10x
error growth trips the gate) — a numerically-broken kernel change can't land
silently. Errors are floored at ``ERR_FLOOR`` so a kernel that happens to be
bit-exact against its reference still yields a meaningful ratio baseline.
"""

from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.decision_scan.ops import decision_scan
from repro.kernels.decode_attention.ops import decode_attention
from repro.kernels.flash_attention.ops import flash_attention
from repro.kernels.lindley_scan.ops import lindley_scan
from repro.kernels.rmsnorm.ops import rmsnorm
from repro.kernels.rmsnorm.ref import rmsnorm_reference
from repro.kernels.ssm_scan.ops import ssm_scan
from repro.kernels.ssm_scan.ref import ssm_scan_reference

from .common import emit, timed

KEY = jax.random.PRNGKey(0)

ERR_FLOOR = 1e-9  # measurement floor for bit-exact kernels (keeps ratios finite)


def _err(out, ref) -> float:
    return max(float(jnp.max(jnp.abs(out - ref))), ERR_FLOOR)


def kernel_rows(out_dir: Path | None = None) -> dict:
    ks = jax.random.split(KEY, 5)
    report: dict[str, dict] = {}

    def record(name: str, us: float, err: float) -> None:
        report[name] = {"us_per_call": us, "max_abs_err": err}
        emit(f"kernel_{name}", us, f"max_err={err:.2e}")

    # flash attention
    q = jax.random.normal(ks[0], (1, 256, 4, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 256, 2, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 256, 2, 64), jnp.float32)
    ref, us = timed(lambda: jax.block_until_ready(flash_attention(q, k, v, impl="xla")))
    out = flash_attention(q, k, v, impl="interpret", blk_q=64, blk_k=64)
    record("flash_attention", us, _err(out, ref))

    # decode attention
    qd = jax.random.normal(ks[0], (2, 1, 8, 64), jnp.float32)
    kc = jax.random.normal(ks[1], (2, 512, 2, 64), jnp.float32)
    vc = jax.random.normal(ks[2], (2, 512, 2, 64), jnp.float32)
    ref, us = timed(lambda: jax.block_until_ready(decode_attention(qd, kc, vc, jnp.int32(511), impl="xla")))
    out = decode_attention(qd, kc, vc, jnp.int32(511), impl="interpret", blk_k=128)
    record("decode_attention", us, _err(out, ref))

    # ssm scan
    B, T, D, N = 2, 128, 128, 8
    dt = jax.nn.softplus(jax.random.normal(ks[0], (B, T, D))) * 0.1
    Bc = jax.random.normal(ks[1], (B, T, N))
    Cc = jax.random.normal(ks[2], (B, T, N))
    u = jax.random.normal(ks[3], (B, T, D))
    A = -jnp.exp(jax.random.normal(ks[4], (D, N)) * 0.5)
    ref, us = timed(lambda: jax.block_until_ready(ssm_scan_reference(dt, Bc, Cc, u, A)[0]))
    out = ssm_scan(dt, Bc, Cc, u, A, impl="interpret", blk_t=32, blk_d=64)
    record("ssm_scan", us, _err(out, ref))

    # rmsnorm
    x = jax.random.normal(ks[0], (8, 128, 512), jnp.float32)
    sc = jax.random.normal(ks[1], (512,)) * 0.1
    ref, us = timed(lambda: jax.block_until_ready(rmsnorm_reference(x, sc)))
    out = rmsnorm(x, sc, impl="interpret")
    record("rmsnorm", us, _err(out, ref))

    # lindley scan (the fleet simulator's per-station recurrence)
    rng = np.random.default_rng(0)
    arr = jnp.asarray(np.cumsum(rng.exponential(0.1, (16, 1024)), axis=1), jnp.float32)
    svc = jnp.asarray(rng.exponential(0.05, (16, 1024)), jnp.float32)
    ref, us = timed(lambda: jax.block_until_ready(lindley_scan(arr, svc, impl="xla")))
    out = lindley_scan(arr, svc, impl="interpret", blk_b=8, blk_t=256)
    record("lindley_scan", us, _err(out, ref))

    # decision scan (the cluster simulator's per-epoch staggered decide step)
    costs = jnp.asarray(rng.exponential(0.05, (256, 16, 5)), jnp.float32)
    coh = jnp.asarray(np.arange(16) % 4, jnp.int32)
    ref, us = timed(lambda: jax.block_until_ready(
        decision_scan(costs, coh, hysteresis=0.15, stagger=4, impl="xla")))
    out = decision_scan(costs, coh, hysteresis=0.15, stagger=4,
                        impl="interpret", blk_n=8, blk_t=64)
    record("decision_scan", us, _err(out, ref))

    if out_dir is not None:
        (out_dir / "BENCH_kernels.json").write_text(json.dumps(report, indent=2))
    return report
