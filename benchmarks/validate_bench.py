"""Validation-harness benchmark: what does the fidelity gate cost?

Times the differential pipeline's stages on the smoke subset of the golden
corpus — analytic cross-check (scalar + vectorized over the whole corpus),
batched simulation, and the end-to-end smoke gate — and emits CSV rows plus a
``BENCH_validate.json`` artifact. ``derived`` carries the fidelity headline
(the gated mean MAPE), so a perf regression AND a model regression both show
up in the same row history.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from repro.fleet import ScenarioBatch, fleet_analytic
from repro.validate import generate_corpus, run_differential, smoke_subset

from .common import emit, timed

SMOKE_N = 20_000


def validate_rows(out_dir: Path | None = None) -> dict:
    entries = generate_corpus(0)
    smoke = smoke_subset(entries)

    # -- analytic cross-check over the FULL corpus ----------------------------
    scns = [e.scenario for e in entries]
    batch = ScenarioBatch.from_scenarios(scns)
    _, us_vec = timed(fleet_analytic, batch)
    t0 = time.perf_counter()
    for s in scns:
        s.analytic()
    us_scalar = (time.perf_counter() - t0) * 1e6
    emit("validate_analytic_vec_corpus", us_vec, f"{len(entries)}_scenarios")
    emit("validate_analytic_scalar_corpus", us_scalar, f"{len(entries)}_scenarios")

    # -- the tier-1 smoke gate end to end ------------------------------------
    t0 = time.perf_counter()
    rep = run_differential(smoke, base_n=SMOKE_N, max_n_factor=2.0,
                           bootstrap=100, sim_cross_count=0)
    gate_s = time.perf_counter() - t0
    emit("validate_smoke_gate", gate_s * 1e6,
         f"mean_mape_{rep.gate.mean_pct:.2f}pct")

    report = {
        "corpus_entries": len(entries),
        "smoke_entries": len(smoke),
        "analytic_vec_us": us_vec,
        "analytic_scalar_us": us_scalar,
        "smoke_gate_s": gate_s,
        "smoke_gate_mean_mape_pct": rep.gate.mean_pct,
        "smoke_gate_passed": rep.passed,
    }
    if out_dir is not None:
        (Path(out_dir) / "BENCH_validate.json").write_text(
            json.dumps(report, indent=2))
    return report


if __name__ == "__main__":
    validate_rows(Path("."))
