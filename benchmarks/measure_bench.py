"""Measurement-subsystem benchmarks: engine throughput, harness rate, fit cost.

Four headline groups in ``BENCH_measure.json``:

  * ``engine.tokens_per_sec`` — real wall-clock decode throughput of the
    reduced smoke config through the jitted engine (machine-bound);
  * ``harness.requests_per_sec`` — end-to-end profiling throughput of the
    simulated-clock harness, i.e. how fast CI can produce a trace
    (machine-bound);
  * ``fit.wall_ms`` — distribution-fitting cost on that trace (machine-bound);
  * ``gate.mean_mape_pct`` / ``gate.p99_mape_pct`` — the measured-gate
    headline numbers on the seeded smoke profile. The simulated clock makes
    these *deterministic*: any drift is a model or engine change, not noise,
    so they are gated in portable mode like the other MAPE headlines.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from .common import emit

SMOKE_ARCH = "starcoder2_3b"
SMOKE_REQUESTS = 120
SMOKE_SEED = 0


def _engine_tokens_per_sec() -> dict:
    """Wall-clock tokens/s of the real engine on the reduced smoke config."""
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import lm
    from repro.serving.engine import Engine, Request, ServeConfig

    cfg = get_config(SMOKE_ARCH).reduced(seq_chunk=8)
    params = lm.init_model(cfg, jax.random.PRNGKey(SMOKE_SEED))
    eng = Engine(cfg, params, ServeConfig(slots=2, max_seq=64))
    eng.warmup([8])
    rng = np.random.default_rng(SMOKE_SEED)
    for rid in range(12):
        eng.submit(Request(rid=rid,
                           prompt=rng.integers(0, cfg.vocab_size, size=8)
                           .astype(np.int32),
                           max_new_tokens=8))
    t0 = time.perf_counter()
    eng.drain()
    wall = time.perf_counter() - t0
    n_tokens = sum(len(r.tokens_out) for r in eng.completed)
    return {"tokens_per_sec": n_tokens / wall, "n_tokens": n_tokens,
            "wall_s": wall}


def measure_rows(out_dir: Path) -> dict:
    from repro.measure import HarnessConfig, build_profile, fit_trace, run_harness
    from repro.validate.measured import run_measured_gate

    engine = _engine_tokens_per_sec()
    emit("measure_engine", engine["wall_s"] * 1e6,
         f"tokens_per_sec={engine['tokens_per_sec']:.1f}")

    hc = HarnessConfig(arch=SMOKE_ARCH, n_requests=SMOKE_REQUESTS, seed=SMOKE_SEED)
    t0 = time.perf_counter()
    trace = run_harness(hc)
    harness_wall = time.perf_counter() - t0
    harness = {"requests_per_sec": len(trace.requests) / harness_wall,
               "n_requests": len(trace.requests), "wall_s": harness_wall}
    emit("measure_harness", harness_wall * 1e6,
         f"requests_per_sec={harness['requests_per_sec']:.1f}")

    t0 = time.perf_counter()
    fit_trace(trace, seed=SMOKE_SEED)
    fit_wall_ms = (time.perf_counter() - t0) * 1e3
    emit("measure_fit", fit_wall_ms * 1e3, f"wall_ms={fit_wall_ms:.1f}")

    profile = build_profile(trace, seed=SMOKE_SEED)
    rep = run_measured_gate(profile)
    gate = {"mean_mape_pct": rep.mean_mape_pct, "p99_mape_pct": rep.p99_mape_pct,
            "rho": rep.rho, "passed": rep.passed}
    emit("measure_gate", 0.0,
         f"mean_mape_pct={rep.mean_mape_pct:.3f} p99_mape_pct={rep.p99_mape_pct:.3f}")

    report = {
        "engine": engine,
        "harness": harness,
        "fit": {"wall_ms": fit_wall_ms},
        "gate": gate,
        "config": {"arch": SMOKE_ARCH, "n_requests": SMOKE_REQUESTS,
                   "seed": SMOKE_SEED, "clock": "simulated"},
    }
    (out_dir / "BENCH_measure.json").write_text(json.dumps(report, indent=2))
    return report
