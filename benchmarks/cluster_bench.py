"""Closed-loop cluster benchmark: decisions/s at fleet scale + equilibrium cost.

Times the two `repro.fleet.cluster` hot paths on the acceptance-criteria
64-client/4-edge cluster and emits CSV rows plus a ``BENCH_cluster.json``
artifact:

  * ``cluster_closed_loop`` — the jitted decision scan + batched analytic
    scoring over a 2000-epoch bandwidth-step trace (headline:
    client-epochs/s, acceptance floor 100k/s on CPU), with the adaptive
    policy scored against every all-clients static on the same trace;
  * ``cluster_equilibrium`` — the fixed-point solver (headline: best-response
    iterations to convergence, a model-behaviour metric that must not creep).
"""

from __future__ import annotations

import json
import time
from pathlib import Path

import numpy as np

from repro.fleet import make_trace, simulate_cluster, solve_equilibrium, step_signal
from repro.launch.cluster_sim import default_cluster

from .common import emit

N_CLIENTS = 64
EPOCHS = 2_000
STAGGER = 8
BW_DROP = 0.15


def cluster_rows(out_dir: Path | None = None) -> dict:
    spec = default_cluster(N_CLIENTS)
    bw0 = float(np.asarray(spec.base.network.bandwidth_Bps))
    third = EPOCHS / 3
    trace = make_trace(
        float(EPOCHS), 1.0,
        bandwidth_Bps=lambda t: step_signal(
            t, [(0, bw0), (third, bw0 * BW_DROP), (2 * third, bw0)]),
        arrival_rate=spec.base.workload.arrival_rate,
    )
    policies = ("adaptive", "on_device") + tuple(
        f"edge[{j}]" for j in range(spec.n_edges))

    # full run (compiles + scores every policy), then a warm adaptive-only
    # pass for the throughput headline
    res = simulate_cluster(spec, trace, policies=policies, stagger=STAGGER, seed=0)
    t0 = time.perf_counter()
    simulate_cluster(spec, trace, policies=("adaptive",), stagger=STAGGER, seed=1)
    loop_s = time.perf_counter() - t0
    rate = res.client_epochs / loop_s
    emit("cluster_closed_loop", loop_s / res.client_epochs * 1e6,
         f"client_epochs_per_sec={rate:.3e};clients={spec.n_clients};epochs={EPOCHS}")

    solve_equilibrium(spec)  # warm
    t0 = time.perf_counter()
    eq = solve_equilibrium(spec)
    eq_s = time.perf_counter() - t0
    emit("cluster_equilibrium", eq_s * 1e6,
         f"iterations={eq.iterations};converged={eq.converged};"
         f"mean_latency_ms={eq.mean_latency_s*1e3:.2f}")

    report = {
        "closed_loop": {
            "clients": spec.n_clients,
            "edges": spec.n_edges,
            "epochs": EPOCHS,
            "stagger": STAGGER,
            "client_epochs": res.client_epochs,
            "client_epochs_per_sec": rate,
            "adaptive_mean_latency_s": res.policies["adaptive"].mean_latency_s,
            "adaptive_wins": res.adaptive_wins,
            "saturated_epochs": res.policies["adaptive"].saturated_epochs,
            "policy_means_s": {
                name: p.mean_latency_s for name, p in res.policies.items()
            },
        },
        "equilibrium": {
            "iterations": eq.iterations,
            "converged": eq.converged,
            "oscillation": eq.oscillation,
            "solve_ms": eq_s * 1e3,
            "mean_latency_s": eq.mean_latency_s,
            "rho_edges": eq.rho_edges.tolist(),
            "counts": eq.counts(),
        },
    }
    if out_dir is not None:
        out_dir.mkdir(parents=True, exist_ok=True)
        (out_dir / "BENCH_cluster.json").write_text(json.dumps(report, indent=2))
    return report


if __name__ == "__main__":
    cluster_rows(Path("experiments/bench"))
