"""Observability-cost benchmarks: tracer overhead, audit throughput.

``BENCH_obs.json`` headline groups:

  * ``tracer.*`` — real-engine decode throughput with observability OFF
    (``tracer=None``), with a DISABLED tracer attached, and with tracing
    fully ON. The portable gate is ``tracer.overhead_gate_pass``: the
    disabled-tracer cost (one predicate per emission site) must stay within
    ``OVERHEAD_BUDGET_PCT`` of the tracer-free throughput — observability
    must be free when off. Absolute tokens/s rows are machine-bound.
  * ``audit.*`` — decision-audit throughput (fully decomposed
    ``AdaptiveOffloadManager.step`` rows/s, machine-bound) and the term
    re-sum invariant over every audited row (``resum_gate_pass``, portable:
    the audit must never tell a story the decision didn't follow).

All three tracer modes run on ONE warmed engine (tracer swapped between
repeats) so the comparison never pays re-JIT noise, and each mode takes its
best-of-``REPEATS`` throughput to de-noise shared CI runners.
"""

from __future__ import annotations

import json
import time
from pathlib import Path

from .common import emit

SMOKE_ARCH = "starcoder2_3b"
SMOKE_SEED = 0
N_REQUESTS = 12
REPEATS = 5
OVERHEAD_BUDGET_PCT = 5.0
AUDIT_EPOCHS = 2000
RESUM_TOL = 1e-9


def _drain_tokens_per_sec(eng, cfg, rng) -> tuple[float, int]:
    """Submit a fresh burst and drain it; returns (tokens/s, tokens)."""
    import numpy as np

    from repro.serving.engine import Request

    for rid in range(N_REQUESTS):
        eng.submit(Request(
            rid=rid,
            prompt=rng.integers(0, cfg.vocab_size, size=8).astype(np.int32),
            max_new_tokens=8))
    t0 = time.perf_counter()
    eng.drain()
    wall = time.perf_counter() - t0
    n_tokens = sum(len(r.tokens_out) for r in eng.completed)
    eng.completed.clear()
    eng.service_log.clear()
    return n_tokens / wall, n_tokens


def _tracer_overhead() -> dict:
    import jax
    import numpy as np

    from repro.configs import get_config
    from repro.models import lm
    from repro.obs import Tracer
    from repro.serving.engine import Engine, ServeConfig

    cfg = get_config(SMOKE_ARCH).reduced(seq_chunk=8)
    params = lm.init_model(cfg, jax.random.PRNGKey(SMOKE_SEED))
    eng = Engine(cfg, params, ServeConfig(slots=2, max_seq=64))
    eng.warmup([8])
    # one untimed drain: the very first drain after warmup still runs ~40%
    # slower (allocator/dispatch caches), which would bias whichever mode
    # goes first
    _drain_tokens_per_sec(eng, cfg, np.random.default_rng(SMOKE_SEED))

    modes = {"none": None, "disabled": Tracer(enabled=False),
             "enabled": Tracer()}
    best: dict[str, float] = {}
    n_spans = 0
    for _ in range(REPEATS):
        # interleave the modes every repeat so machine noise (thermal, sibling
        # jobs) lands on all three alike instead of biasing one
        for mode, tracer in modes.items():
            eng.tracer = tracer
            eng._trace = tracer is not None and tracer.enabled
            rng = np.random.default_rng(SMOKE_SEED)
            tps, _ = _drain_tokens_per_sec(eng, cfg, rng)
            best[mode] = max(best.get(mode, 0.0), tps)
    n_spans = len(modes["enabled"].spans)
    assert len(modes["disabled"].spans) == 0, "disabled tracer recorded spans"

    disabled_overhead = (best["none"] - best["disabled"]) / best["none"] * 100.0
    enabled_overhead = (best["none"] - best["enabled"]) / best["none"] * 100.0
    return {
        "tokens_per_sec_none": best["none"],
        "tokens_per_sec_disabled": best["disabled"],
        "tokens_per_sec_enabled": best["enabled"],
        "disabled_overhead_pct": disabled_overhead,
        "enabled_overhead_pct": enabled_overhead,
        "overhead_budget_pct": OVERHEAD_BUDGET_PCT,
        "overhead_gate_pass": float(disabled_overhead <= OVERHEAD_BUDGET_PCT),
        "n_spans_enabled": n_spans,
    }


def _audit_throughput() -> dict:
    from repro.core import EdgeSpec, NetworkPath, Scenario, ServiceModel, Tier, Workload
    from repro.obs import AuditLog

    scn = Scenario(
        workload=Workload(arrival_rate=8.0, req_bytes=200_000, res_bytes=40_000),
        device=Tier("device", 0.080, service_model=ServiceModel.EXPONENTIAL),
        edges=(
            EdgeSpec(Tier("edge0", 0.010, service_model=ServiceModel.EXPONENTIAL)),
            EdgeSpec(Tier("edge1", 0.012, service_model=ServiceModel.EXPONENTIAL)),
        ),
        network=NetworkPath(bandwidth_Bps=2.5e6),
        name="obs-bench",
    )
    auditor = AuditLog()
    mgr = scn.manager(auditor=auditor)
    edges = [e.to_state(scn.workload) for e in scn.edges]
    snapshot = {
        "workload": scn.workload,
        "lam_dev": scn.workload.arrival_rate,
        "edges": edges,
    }
    t0 = time.perf_counter()
    for i in range(AUDIT_EPOCHS):
        # sweep the bandwidth through the crossover so the audited decisions
        # (and the terms behind them) actually vary across rows
        snapshot["bandwidth_Bps"] = 2.5e6 * (0.2 + 1.8 * (i % 50) / 49.0)
        mgr.step(float(i), snapshot)
    wall = time.perf_counter() - t0
    err = auditor.max_resum_error()
    return {
        "rows_per_sec": len(auditor) / wall,
        "n_rows": len(auditor),
        "max_resum_error": err,
        "resum_tol": RESUM_TOL,
        "resum_gate_pass": float(err <= RESUM_TOL),
    }


def obs_rows(out_dir: Path) -> dict:
    tracer = _tracer_overhead()
    emit("obs_tracer", 0.0,
         f"disabled_overhead_pct={tracer['disabled_overhead_pct']:.2f} "
         f"gate_pass={tracer['overhead_gate_pass']:.0f}")

    audit = _audit_throughput()
    emit("obs_audit", 0.0,
         f"rows_per_sec={audit['rows_per_sec']:.0f} "
         f"max_resum_error={audit['max_resum_error']:.1e}")

    report = {
        "tracer": tracer,
        "audit": audit,
        "config": {"arch": SMOKE_ARCH, "seed": SMOKE_SEED,
                   "n_requests": N_REQUESTS, "repeats": REPEATS,
                   "audit_epochs": AUDIT_EPOCHS},
    }
    (out_dir / "BENCH_obs.json").write_text(json.dumps(report, indent=2))
    return report
