"""Benchmark harness: one registered runner per bench family.

One entrypoint executes every bench (or a ``--only`` subset), prints the
``name,us_per_call,derived`` CSV contract to stdout, and writes each family's
JSON artifact under ``--out``:

  * ``paper_figures`` -> BENCH_paper_figures.json (per-figure headline numbers)
  * ``fleet``         -> BENCH_fleet.json (scalar-vs-vectorized throughput)
  * ``cluster``       -> BENCH_cluster.json (closed-loop client-epochs/s +
                         equilibrium iterations)
  * ``meanfield``     -> BENCH_meanfield.json (million-client diurnal-day
                         throughput + mean-field-vs-exact gated MAPE)
  * ``validate``      -> BENCH_validate.json (fidelity-gate cost + headline MAPE)
  * ``tail``          -> BENCH_tail.json (sojourn-quantile throughput +
                         asymptote-vs-Euler gap + station_pass speedup)
  * ``kernels``       -> BENCH_kernels.json (per-kernel reference latency +
                         validated interpret-mode max-abs error)
  * ``measure``       -> BENCH_measure.json (engine tokens/s, harness
                         requests/s, fit wall time, measured-gate MAPE)
  * ``obs``           -> BENCH_obs.json (tracer-disabled overhead gate,
                         enabled-tracer tokens/s, audit rows/s + re-sum gate)
  * ``plan``          -> BENCH_plan.json (provisioning-solver wall time,
                         equilibrium solves vs grid size, plan picked)
  * ``roofline``      -> CSV rows from dry-run artifacts, when present

Every BENCH_*.json written by a run gets a ``manifest`` block stamped in
(``repro.obs.run_manifest``: seed-free provenance — git sha, config hash,
package versions; no timestamps) so check_regression can say when a baseline
came from different provenance.

An unknown ``--only`` family is an error (nonzero exit, known families
listed) — CI relies on that exit code, so a typo can never silently run
nothing and upload an empty artifact as green.

The family list is not declared here: ``BENCHES`` derives from the single
experiment registry in ``repro.exp.spec``, so this CLI, ``repro.launch
.reproduce``, and the regression gate can never disagree about what exists.
This entry point keeps its historical flags, CSV contract, and exit codes.

Usage:
  PYTHONPATH=src python -m benchmarks.run --out experiments/bench
  PYTHONPATH=src python -m benchmarks.run --only fleet --only kernels
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def run_paper_figures(out_dir: Path) -> dict:
    from . import paper_figures as F

    report = {
        "fig2_mape_pct": F.fig2_workload_characteristics(),
        "fig3_mape_pct": F.fig3_complex_models(),
        "fig4_crossovers_mbps": F.fig4_bandwidth_crossovers(),
        "fig5a_split_mape_pct": F.fig5a_split_processing(),
        "fig5b_offload_wins": F.fig5b_request_rate(),
        "fig5c_crossover_m": F.fig5c_multitenancy(),
        "fig6_strategies": F.fig6_network_adaptation(),
        "fig7_targets": F.fig7_multitenant_adaptation(),
        "model_accuracy": F.model_accuracy_suite(),
    }
    (out_dir / "BENCH_paper_figures.json").write_text(json.dumps(report, indent=2))
    return report


def run_roofline(out_dir: Path) -> dict:
    # roofline table from dry-run artifacts, if present
    roof = Path("experiments/roofline")
    if roof.is_dir() and any(roof.glob("*.json")):
        from .roofline_report import print_roofline_rows

        print_roofline_rows(roof)
    return {}


def _family_runner(payload: str):
    """A ``fn(out_dir) -> report`` wrapper over a registry payload, resolved
    lazily so importing this module stays cheap (and so the registry's
    ``benchmarks.run:*`` payloads don't import-cycle at module load)."""
    def run(out_dir: Path) -> dict:
        from repro.exp.runner import resolve_payload

        return resolve_payload(payload)(out_dir)
    return run


def _benches() -> dict:
    from repro.exp.spec import bench_family_specs

    return {family: _family_runner(spec.payload)
            for family, spec in bench_family_specs().items()}


#: family -> runner, derived from the ONE experiment registry
#: (``repro.exp.spec``): a family added there is automatically runnable
#: here, reproducible via ``repro.launch.reproduce``, and checked for
#: registry completeness by tests/test_exp.py
BENCHES = _benches()


def stamp_manifests(out_dir: Path) -> None:
    """Attach the run-provenance manifest to every BENCH_*.json artifact."""
    from repro.obs import run_manifest

    manifest = run_manifest()
    for path in sorted(out_dir.glob("BENCH_*.json")):
        doc = json.loads(path.read_text())
        doc["manifest"] = manifest
        path.write_text(json.dumps(doc, indent=2))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    # families are validated by hand (not argparse choices) so an unknown
    # name exits nonzero with the registry listed — and stays that way as
    # the registry grows, instead of silently running nothing
    ap.add_argument("--only", action="append", metavar="FAMILY",
                    help="run only these bench families (repeatable and/or "
                         "comma-separated; default all; "
                         f"known: {', '.join(sorted(BENCHES))})")
    ap.add_argument("--out", type=Path, default=Path("experiments/bench"),
                    help="directory for JSON artifacts")
    args = ap.parse_args(argv)

    # accept --only a,b alongside repeated --only a --only b; empty segments
    # from stray commas are dropped so "a,,b" and "a," don't become families
    selected = [n.strip() for item in (args.only or [])
                for n in item.split(",") if n.strip()]
    if args.only and not selected:
        print(f"error: --only given but no family names parsed "
              f"(known: {', '.join(sorted(BENCHES))})", file=sys.stderr)
        return 2
    unknown = [n for n in selected if n not in BENCHES]
    if unknown:
        print(f"error: unknown bench famil{'y' if len(unknown) == 1 else 'ies'} "
              f"{', '.join(repr(n) for n in unknown)} "
              f"(known: {', '.join(sorted(BENCHES))})", file=sys.stderr)
        return 2

    names = selected or list(BENCHES)
    args.out.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    for name in names:
        BENCHES[name](args.out)
    stamp_manifests(args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
