"""Benchmark harness: one registered runner per bench family.

One entrypoint executes every bench (or a ``--only`` subset), prints the
``name,us_per_call,derived`` CSV contract to stdout, and writes each family's
JSON artifact under ``--out``:

  * ``paper_figures`` -> BENCH_paper_figures.json (per-figure headline numbers)
  * ``fleet``         -> BENCH_fleet.json (scalar-vs-vectorized throughput)
  * ``cluster``       -> BENCH_cluster.json (closed-loop client-epochs/s +
                         equilibrium iterations)
  * ``meanfield``     -> BENCH_meanfield.json (million-client diurnal-day
                         throughput + mean-field-vs-exact gated MAPE)
  * ``validate``      -> BENCH_validate.json (fidelity-gate cost + headline MAPE)
  * ``tail``          -> BENCH_tail.json (sojourn-quantile throughput +
                         asymptote-vs-Euler gap + station_pass speedup)
  * ``kernels``       -> BENCH_kernels.json (per-kernel reference latency +
                         validated interpret-mode max-abs error)
  * ``measure``       -> BENCH_measure.json (engine tokens/s, harness
                         requests/s, fit wall time, measured-gate MAPE)
  * ``obs``           -> BENCH_obs.json (tracer-disabled overhead gate,
                         enabled-tracer tokens/s, audit rows/s + re-sum gate)
  * ``plan``          -> BENCH_plan.json (provisioning-solver wall time,
                         equilibrium solves vs grid size, plan picked)
  * ``roofline``      -> CSV rows from dry-run artifacts, when present

Every BENCH_*.json written by a run gets a ``manifest`` block stamped in
(``repro.obs.run_manifest``: seed-free provenance — git sha, config hash,
package versions; no timestamps) so check_regression can say when a baseline
came from different provenance.

An unknown ``--only`` family is an error (nonzero exit, known families
listed) — CI relies on that exit code, so a typo can never silently run
nothing and upload an empty artifact as green.

Usage:
  PYTHONPATH=src python -m benchmarks.run --out experiments/bench
  PYTHONPATH=src python -m benchmarks.run --only fleet --only kernels
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path


def run_paper_figures(out_dir: Path) -> dict:
    from . import paper_figures as F

    report = {
        "fig2_mape_pct": F.fig2_workload_characteristics(),
        "fig3_mape_pct": F.fig3_complex_models(),
        "fig4_crossovers_mbps": F.fig4_bandwidth_crossovers(),
        "fig5a_split_mape_pct": F.fig5a_split_processing(),
        "fig5b_offload_wins": F.fig5b_request_rate(),
        "fig5c_crossover_m": F.fig5c_multitenancy(),
        "fig6_strategies": F.fig6_network_adaptation(),
        "fig7_targets": F.fig7_multitenant_adaptation(),
        "model_accuracy": F.model_accuracy_suite(),
    }
    (out_dir / "BENCH_paper_figures.json").write_text(json.dumps(report, indent=2))
    return report


def run_kernels(out_dir: Path) -> dict:
    # kernel micro-benchmarks (interpret-mode correctness latency on CPU is
    # not a perf claim; rows document call overhead + validated tolerance)
    from .kernel_bench import kernel_rows

    return kernel_rows(out_dir)


def run_measure(out_dir: Path) -> dict:
    from .measure_bench import measure_rows

    return measure_rows(out_dir)


def run_fleet(out_dir: Path) -> dict:
    from .fleet_bench import fleet_rows

    return fleet_rows(out_dir)


def run_cluster(out_dir: Path) -> dict:
    from .cluster_bench import cluster_rows

    return cluster_rows(out_dir)


def run_meanfield(out_dir: Path) -> dict:
    from .meanfield_bench import meanfield_rows

    return meanfield_rows(out_dir)


def run_validate(out_dir: Path) -> dict:
    from .validate_bench import validate_rows

    return validate_rows(out_dir)


def run_tail(out_dir: Path) -> dict:
    from .tail_bench import tail_rows

    return tail_rows(out_dir)


def run_obs(out_dir: Path) -> dict:
    from .obs_bench import obs_rows

    return obs_rows(out_dir)


def run_plan(out_dir: Path) -> dict:
    from .plan_bench import plan_rows

    return plan_rows(out_dir)


def run_roofline(out_dir: Path) -> dict:
    # roofline table from dry-run artifacts, if present
    roof = Path("experiments/roofline")
    if roof.is_dir() and any(roof.glob("*.json")):
        from .roofline_report import print_roofline_rows

        print_roofline_rows(roof)
    return {}


BENCHES = {
    "paper_figures": run_paper_figures,
    "kernels": run_kernels,
    "fleet": run_fleet,
    "cluster": run_cluster,
    "meanfield": run_meanfield,
    "validate": run_validate,
    "tail": run_tail,
    "measure": run_measure,
    "obs": run_obs,
    "plan": run_plan,
    "roofline": run_roofline,
}


def stamp_manifests(out_dir: Path) -> None:
    """Attach the run-provenance manifest to every BENCH_*.json artifact."""
    from repro.obs import run_manifest

    manifest = run_manifest()
    for path in sorted(out_dir.glob("BENCH_*.json")):
        doc = json.loads(path.read_text())
        doc["manifest"] = manifest
        path.write_text(json.dumps(doc, indent=2))


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    # families are validated by hand (not argparse choices) so an unknown
    # name exits nonzero with the registry listed — and stays that way as
    # the registry grows, instead of silently running nothing
    ap.add_argument("--only", action="append", metavar="FAMILY",
                    help="run only these bench families (repeatable and/or "
                         "comma-separated; default all; "
                         f"known: {', '.join(sorted(BENCHES))})")
    ap.add_argument("--out", type=Path, default=Path("experiments/bench"),
                    help="directory for JSON artifacts")
    args = ap.parse_args(argv)

    # accept --only a,b alongside repeated --only a --only b; empty segments
    # from stray commas are dropped so "a,,b" and "a," don't become families
    selected = [n.strip() for item in (args.only or [])
                for n in item.split(",") if n.strip()]
    if args.only and not selected:
        print(f"error: --only given but no family names parsed "
              f"(known: {', '.join(sorted(BENCHES))})", file=sys.stderr)
        return 2
    unknown = [n for n in selected if n not in BENCHES]
    if unknown:
        print(f"error: unknown bench famil{'y' if len(unknown) == 1 else 'ies'} "
              f"{', '.join(repr(n) for n in unknown)} "
              f"(known: {', '.join(sorted(BENCHES))})", file=sys.stderr)
        return 2

    names = selected or list(BENCHES)
    args.out.mkdir(parents=True, exist_ok=True)
    print("name,us_per_call,derived")
    for name in names:
        BENCHES[name](args.out)
    stamp_manifests(args.out)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
