"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV. The roofline report (our §Roofline
deliverable) is appended when dry-run artifacts exist under
experiments/dryrun (see repro.launch.dryrun / repro.launch.roofline_run).
"""

from __future__ import annotations

import sys
from pathlib import Path


def main() -> None:
    from . import paper_figures as F

    print("name,us_per_call,derived")
    F.fig2_workload_characteristics()
    F.fig3_complex_models()
    F.fig4_bandwidth_crossovers()
    F.fig5a_split_processing()
    F.fig5b_request_rate()
    F.fig5c_multitenancy()
    F.fig6_network_adaptation()
    F.fig7_multitenant_adaptation()
    F.model_accuracy_suite()

    # kernel micro-benchmarks (interpret-mode correctness latency on CPU is
    # not a perf claim; rows document call overhead + validated tolerance)
    from .kernel_bench import kernel_rows

    kernel_rows()

    # roofline table from dry-run artifacts, if present
    roof = Path("experiments/roofline")
    if roof.is_dir() and any(roof.glob("*.json")):
        from .roofline_report import print_roofline_rows

        print_roofline_rows(roof)


if __name__ == "__main__":
    main()
