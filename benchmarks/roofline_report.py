"""Roofline rows for the benchmark CSV, read from experiments/roofline JSONs."""

from __future__ import annotations

import json
from pathlib import Path

from .common import emit


def print_roofline_rows(directory: Path) -> None:
    for f in sorted(directory.glob("*.json")):
        if f.name == "manifest.json":  # dir-level provenance, not a cell
            continue
        r = json.loads(f.read_text())
        name = f"roofline_{r['arch']}_{r['shape']}_{r['mesh']}"
        derived = (
            f"compute_s={r['compute_s']:.3e};memory_s={r['memory_s']:.3e};"
            f"collective_s={r['collective_s']:.3e};dominant={r['dominant']};"
            f"useful_ratio={r['useful_ratio']:.3f};roofline_fraction={r.get('roofline_fraction', 0):.3f}"
        )
        emit(name, 0.0, derived)
