"""Shared helpers for the paper-figure benchmarks.

Every benchmark prints ``name,us_per_call,derived`` CSV rows (harness
contract) where ``derived`` carries the figure's headline quantity (MAPE,
crossover location, ...). Latency predictions are closed-form (microseconds
to evaluate); observations come from the discrete-event simulator.
"""

from __future__ import annotations

import time
from typing import Callable

import numpy as np

__all__ = ["timed", "mape", "emit", "Row"]


def mape(pred, obs) -> float:
    pred = np.asarray(pred, dtype=np.float64)
    obs = np.asarray(obs, dtype=np.float64)
    return float(np.mean(np.abs(pred - obs) / obs) * 100.0)


def timed(fn: Callable, *args, repeat: int = 3, **kw):
    """(result, microseconds-per-call)."""
    fn(*args, **kw)  # warm
    t0 = time.perf_counter()
    for _ in range(repeat):
        out = fn(*args, **kw)
    us = (time.perf_counter() - t0) / repeat * 1e6
    return out, us


def emit(name: str, us: float, derived) -> None:
    print(f"{name},{us:.1f},{derived}")
