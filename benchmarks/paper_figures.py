"""Reproductions of the paper's figures/tables, one function per artifact.

Each reproduces the *shape* of the published experiment with the discrete-
event simulator as ground truth (DESIGN.md §1 C8): the same workloads-vs-
devices grid (Fig 2), complex-model M/M/1 case (Fig 3), bandwidth sweeps
(Fig 4), split processing (Fig 5a), request-rate sweep (Fig 5b), tenancy
sweep (Fig 5c), and the two adaptive-manager case studies (Figs 6-7).

Every experiment is expressed as a ``repro.core.Scenario`` — the unified
validated spec — and driven through ``analytic`` / ``simulate`` /
``crossovers`` / ``Scenario.manager``, so prediction, validation, and the
adaptive manager all consume the exact same operating-point description.

Tier service times are representative of published Jetson-TX2 / Orin-Nano /
A2-class inference measurements for the paper's three DNN workloads
(MobileNetV2 / InceptionV4 / YOLOv8n) — the paper's own two-level
methodology: profiled service times go IN, the queueing models come OUT.
With these inputs every qualitative crossover in the paper reproduces:
TX2/Orin beat offloading for MobileNetV2 & YOLOv8n at 5 Mbps (Fig 2a/b/e/f),
offloading wins InceptionV4 (Fig 2c/d), the Fig 6 bandwidth schedule flips to
on-device only at 2 Mbps, and the Fig 7 load sequence walks E1 -> E2 -> local.
"""

from __future__ import annotations

import zlib
from dataclasses import replace

import numpy as np

from repro.core import simulation as S
from repro.core.latency import NetworkPath, ServiceModel, Tier, Workload
from repro.core.multitenant import TenantStream
from repro.core.scenario import EdgeSpec, Scenario, analytic, crossovers, simulate
from repro.core.split import LayerProfile, SplitPlanner

from .common import emit, mape, timed

# profiled-style service times (ms) per (workload, accelerator); see docstring
SERVICE_MS = {
    "mobilenetv2": {"tx2": 25.0, "orin": 8.0, "a2": 3.5, "rtx4070": 1.2},
    "inceptionv4": {"tx2": 150.0, "orin": 85.0, "a2": 28.0, "rtx4070": 9.0},
    "yolov8n": {"tx2": 50.0, "orin": 28.0, "a2": 19.0, "rtx4070": 6.0},
}
# effective edge parallelism k (paper §4.1: fitted per workload; heavy models
# occupy the whole A2, light ones batch well)
K_EDGE = {"mobilenetv2": 4.0, "inceptionv4": 1.0, "yolov8n": 1.0}
WORKLOAD_GFLOPS = {"mobilenetv2": 0.6e9, "inceptionv4": 6.3e9, "yolov8n": 8.7e9}
PAYLOADS = {  # (D_req, D_res) bytes — compressed-frame sizes by input res
    "mobilenetv2": (15_000, 1_000),
    "inceptionv4": (30_000, 1_000),
    "yolov8n": (90_000, 4_000),
}


def service_s(workload: str, hw: str) -> float:
    return SERVICE_MS[workload][hw] / 1e3


def _seed(tag: str, mod: int = 1000) -> int:
    """Stable per-tag seed (str hash() is randomised per interpreter run)."""
    return zlib.crc32(tag.encode()) % mod


def scenario(
    wname: str,
    dev_hw: str,
    *,
    edge_hw: str = "a2",
    lam: float = 2.0,
    mbps: float = 5.0,
    model: ServiceModel = ServiceModel.DETERMINISTIC,
    background: tuple[TenantStream, ...] = (),
    allow_unstable: bool = False,
) -> Scenario:
    """One paper operating point as a validated Scenario spec."""
    dreq, dres = PAYLOADS[wname]
    return Scenario(
        workload=Workload(lam, dreq, dres, name=wname),
        device=Tier(dev_hw, service_s(wname, dev_hw), service_model=model),
        network=NetworkPath(mbps * 1e6 / 8),
        edges=(
            EdgeSpec(
                Tier(edge_hw, service_s(wname, edge_hw),
                     parallelism_k=K_EDGE[wname], service_model=model),
                background=background,
            ),
        ),
        allow_unstable=allow_unstable,
        name=f"{wname}:{dev_hw}->{edge_hw}",
    )


# ---------------------------------------------------------------------------
# Fig. 2: workload characteristics (3 DNNs x 2 devices vs A2 offload, 5 Mbps)
# ---------------------------------------------------------------------------


def fig2_workload_characteristics() -> float:
    errors = []
    for wname in WORKLOAD_GFLOPS:
        for dev_hw in ("tx2", "orin"):
            scn = scenario(wname, dev_hw)
            pred = analytic(scn)
            sim_dev = simulate(scn, "on_device", n=60_000, seed=_seed(wname))
            errors.append(mape(float(pred["on_device"].total), sim_dev.mean))
        # the edge side is device-independent: validate it once per workload
        scn_edge = scenario(wname, "tx2")
        pred_edge = float(analytic(scn_edge)["edge[0]"].total)
        sim_edge = simulate(scn_edge, "edge[0]", n=60_000, seed=_seed(wname, 997))
        errors.append(mape(pred_edge, sim_edge.mean))
    overall = float(np.mean(errors))
    _, us = timed(lambda: analytic(scn_edge))
    emit("fig2_workload_characteristics", us, f"mape_pct={overall:.2f}")
    return overall


# ---------------------------------------------------------------------------
# Fig. 3: LSTM / LLM — variable service -> M/M/1 formulation
# ---------------------------------------------------------------------------


def fig3_complex_models() -> float:
    errors = []
    for name, (s_dev, s_edge, dreq, dres) in {
        "lstm": (0.020, 0.006, 4_000, 500),
        "llm": (0.800, 0.180, 2_000, 2_000),
    }.items():
        scn = Scenario(
            workload=Workload(0.8 if name == "llm" else 2.0, dreq, dres, name=name),
            device=Tier("orin", s_dev, service_model=ServiceModel.EXPONENTIAL),
            network=NetworkPath(5e6 / 8),
            edges=(EdgeSpec(Tier("a2", s_edge, service_model=ServiceModel.EXPONENTIAL)),),
            name=name,
        )
        pred = analytic(scn)
        sim_dev = simulate(scn, "on_device", n=80_000, seed=11)
        sim_edge = simulate(scn, "edge[0]", n=80_000, seed=13)
        errors += [
            mape(float(pred["on_device"].total), sim_dev.mean),
            mape(float(pred["edge[0]"].total), sim_edge.mean),
        ]
        # offloading should win for the heavy LLM (paper: "even more pronounced")
        assert pred.best_strategy == "edge[0]" or name == "lstm"
    overall = float(np.mean(errors))
    _, us = timed(lambda: analytic(scn))
    emit("fig3_complex_models", us, f"mape_pct={overall:.2f}")
    return overall


# ---------------------------------------------------------------------------
# Fig. 4: bandwidth sweeps and crossover points
# ---------------------------------------------------------------------------


def fig4_bandwidth_crossovers() -> dict:
    out = {}
    wname = "inceptionv4"
    for edge_hw in ("rtx4070", "a2"):
        for dev_hw in ("tx2", "orin"):
            scn = scenario(wname, dev_hw, edge_hw=edge_hw, allow_unstable=True)
            c = crossovers(scn, "bandwidth")
            out[f"{dev_hw}->{edge_hw}"] = None if c.value is None else c.value * 8 / 1e6
    # the faster device needs MORE bandwidth before offloading pays (Fig 4a)
    _, us = timed(lambda: crossovers(scenario(wname, "tx2", allow_unstable=True), "bandwidth"))
    ok = (out["tx2->rtx4070"] or 0) <= (out["orin->rtx4070"] or np.inf)
    emit("fig4_bandwidth_crossovers", us,
         f"tx2@rtx={out['tx2->rtx4070']:.2f}Mbps;orin@rtx={out['orin->rtx4070']:.2f}Mbps;ordered={ok}")
    return out


# ---------------------------------------------------------------------------
# Fig. 5a: collaborative (split) processing of a layered model
# ---------------------------------------------------------------------------


def fig5a_split_processing() -> float:
    wname = "mobilenetv2"
    # split processing ships UNCOMPRESSED tensors: SP0 = the raw 224x224x3
    # input (150 KB), later SPs = raw intermediate activations (paper §4.6:
    # "intermediate results of later layers grow in size")
    dreq, dres = 150_528, 1_000
    wl = Workload(2.0, dreq, dres)
    dev = Tier("orin", 1.0)  # per-layer services below are what matter
    edge = Tier("a2", 1.0, parallelism_k=K_EDGE[wname])
    # 8 layers; later layers have growing intermediate activations (paper §4.6)
    total_dev = service_s(wname, "orin")
    total_edge = service_s(wname, "a2")
    layers = [
        LayerProfile(
            dev_service_s=total_dev / 8,
            edge_service_s=total_edge / 8,
            out_bytes=120_000 + 45_000 * i,
        )
        for i in range(8)
    ]
    planner = SplitPlanner(layers, wl)
    net = NetworkPath(50e6 / 8)  # 50 Mbps (paper's split experiment)
    plan = planner.plan(dev, edge, net)
    # validate three split points against simulation
    errs = []
    for idx in (0, 4, len(layers)):
        sp = planner.candidate(idx)
        pred = float(__import__("repro.core.split", fromlist=["split_latency"]).split_latency(
            wl, dev, edge, net, sp))
        sim = S.simulate_split(
            wl.arrival_rate,
            S.Deterministic(sp.dev_service_s) if sp.dev_service_s else S.Deterministic(0.0),
            S.Deterministic(sp.edge_service_s) if sp.edge_service_s else S.Deterministic(0.0),
            k_edge=int(K_EDGE[wname]), bandwidth_Bps=net.bandwidth_Bps,
            inter_bytes=sp.inter_bytes, res_bytes=wl.res_bytes, n=50_000, seed=idx,
        )
        errs.append(mape(pred, sim.mean))
    _, us = timed(lambda: planner.plan(dev, edge, net))
    emit("fig5a_split_processing", us,
         f"best_idx={plan.index};strategy={plan.strategy};mape_pct={np.mean(errs):.2f}")
    return float(np.mean(errs))


# ---------------------------------------------------------------------------
# Fig. 5b: request-rate sweep at 10 vs 20 Mbps
# ---------------------------------------------------------------------------


def fig5b_request_rate() -> dict:
    wname = "mobilenetv2"
    base = scenario(wname, "orin", lam=1.0, allow_unstable=True)
    out = {}
    for mbps in (10, 20):
        at_bw = base.replaced("network.bandwidth_Bps", mbps * 1e6 / 8)
        wins = 0
        for scn in at_bw.sweep("workload.arrival_rate", np.linspace(1, 120, 40)):
            totals = analytic(scn).totals()
            if np.isfinite(totals["edge[0]"]) and totals["edge[0]"] < totals["on_device"]:
                wins += 1
        out[mbps] = wins
    _, us = timed(lambda: analytic(base))
    emit("fig5b_request_rate", us,
         f"offload_wins@10Mbps={out[10]}/40;@20Mbps={out[20]}/40;faster_net_wins_more={out[20] >= out[10]}")
    return out


# ---------------------------------------------------------------------------
# Fig. 5c: multi-tenancy sweep (co-located InceptionV4 apps)
# ---------------------------------------------------------------------------


def fig5c_multitenancy() -> int | None:
    wname = "inceptionv4"
    scn = scenario(wname, "tx2", allow_unstable=True)
    c = crossovers(scn, "tenancy", max_tenants=128)
    m_star = None if c.value is None else int(c.value)
    # validate the latency around m_star against simulation: a scenario whose
    # edge hosts (m-1) background copies of the same app IS the m-tenant case
    errs = []
    if m_star and m_star > 1:
        template = scn.edges[0].own_stream(scn.workload)
        for m in (max(1, m_star - 2), m_star):
            scn_m = scn.replaced("edges[0].background", (template,) * (m - 1))
            pred = float(analytic(scn_m)["edge[0]"].total)
            sim = simulate(scn_m, "edge[0]",
                           n=max(4000, 40000 // m) * m, seed=m)
            errs.append(mape(pred, sim.stream_mean(0)))
    _, us = timed(lambda: crossovers(scn, "tenancy", max_tenants=8))
    emit("fig5c_multitenancy", us,
         f"crossover_m={m_star};mape_pct={np.mean(errs):.2f}" if errs else f"crossover_m={m_star}")
    return m_star


# ---------------------------------------------------------------------------
# Fig. 6: adaptive manager under bandwidth dynamics (20 -> 10 -> 2 -> 20 Mbps)
# ---------------------------------------------------------------------------


def fig6_network_adaptation() -> list[str]:
    scn = scenario("mobilenetv2", "tx2", lam=10.0, mbps=20.0)
    mgr = scn.manager()
    states = scn.edge_states()
    strategies = []
    for t, bw in [(0, 20e6 / 8), (20, 10e6 / 8), (40, 2e6 / 8), (60, 20e6 / 8)]:
        snap = scn.snapshot(time_s=t, bandwidth_Bps=bw)
        strategies.append(mgr.decide(scn.workload, snap, states).strategy)
    _, us = timed(lambda: mgr.decide(scn.workload, scn.snapshot(bandwidth_Bps=2.5e6), states))
    emit("fig6_network_adaptation", us, ";".join(strategies))
    return strategies


# ---------------------------------------------------------------------------
# Fig. 7: adaptive manager across multi-tenant edge servers
# ---------------------------------------------------------------------------


def fig7_multitenant_adaptation() -> list[str]:
    wname = "yolov8n"
    s_edge = service_s(wname, "a2")

    def phase(bg1: float, bg2: float) -> Scenario:
        bg = lambda lam: (TenantStream(lam, s_edge),)
        base = scenario(wname, "tx2", lam=10.0, mbps=40.0, allow_unstable=True)
        e = base.edges[0].tier
        return replace(
            base,
            edges=(
                EdgeSpec(replace(e, name="E1"), background=bg(bg1)),
                EdgeSpec(replace(e, name="E2"), background=bg(bg2)),
            ),
        )

    # background load walks E1 -> E2 -> everything saturated (own 10 rps adds
    # on top; edge capacity is 1/s_edge ~= 52.6 rps)
    phases = [phase(10, 30), phase(50, 30), phase(50, 50)]
    mgr = phases[0].manager()
    targets = []
    for scn in phases:
        d = mgr.decide(scn.workload, scn.snapshot(), scn.edge_states())
        targets.append(d.target_name)
    _, us = timed(lambda: mgr.decide(phases[0].workload, phases[0].snapshot(),
                                     phases[0].edge_states()))
    emit("fig7_multitenant_adaptation", us, ";".join(targets))
    return targets


# ---------------------------------------------------------------------------
# Aggregate accuracy (the paper's 2.2% MAPE / 91.5% within 5% / 100% within 10%)
# ---------------------------------------------------------------------------


def model_accuracy_suite() -> dict:
    preds, obs = [], []
    grid = [
        (wname, lam, mbps)
        for wname in WORKLOAD_GFLOPS
        for lam in (1.0, 2.0, 5.0)
        for mbps in (5, 20)
    ]
    for i, (wname, lam, mbps) in enumerate(grid):
        scn = scenario(wname, "tx2", lam=lam, mbps=mbps, allow_unstable=True)
        pred = analytic(scn).totals()
        if np.isfinite(pred["edge[0]"]):
            sim = simulate(scn, "edge[0]", n=60_000, seed=100 + i)
            preds.append(pred["edge[0]"])
            obs.append(sim.mean)
        sim_d = simulate(scn, "on_device", n=60_000, seed=200 + i)
        preds.append(pred["on_device"])
        obs.append(sim_d.mean)
    preds, obs = np.array(preds), np.array(obs)
    rel = np.abs(preds - obs) / obs * 100
    out = {
        "mape_pct": float(rel.mean()),
        "within_5pct": float((rel <= 5).mean() * 100),
        "within_10pct": float((rel <= 10).mean() * 100),
        "n": int(len(rel)),
    }
    _, us = timed(lambda: analytic(scenario("mobilenetv2", "tx2")))
    emit("model_accuracy_suite", us,
         f"mape_pct={out['mape_pct']:.2f};within5={out['within_5pct']:.1f};within10={out['within_10pct']:.1f};n={out['n']}")
    return out
