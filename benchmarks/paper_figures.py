"""Reproductions of the paper's figures/tables, one function per artifact.

Each reproduces the *shape* of the published experiment with the discrete-
event simulator as ground truth (DESIGN.md §1 C8): the same workloads-vs-
devices grid (Fig 2), complex-model M/M/1 case (Fig 3), bandwidth sweeps
(Fig 4), split processing (Fig 5a), request-rate sweep (Fig 5b), tenancy
sweep (Fig 5c), and the two adaptive-manager case studies (Figs 6-7).

Tier service times are representative of published Jetson-TX2 / Orin-Nano /
A2-class inference measurements for the paper's three DNN workloads
(MobileNetV2 / InceptionV4 / YOLOv8n) — the paper's own two-level
methodology: profiled service times go IN, the queueing models come OUT.
With these inputs every qualitative crossover in the paper reproduces:
TX2/Orin beat offloading for MobileNetV2 & YOLOv8n at 5 Mbps (Fig 2a/b/e/f),
offloading wins InceptionV4 (Fig 2c/d), the Fig 6 bandwidth schedule flips to
on-device only at 2 Mbps, and the Fig 7 load sequence walks E1 -> E2 -> local.
"""

from __future__ import annotations

from dataclasses import replace

import numpy as np

from repro.core import simulation as S
from repro.core.crossover import bandwidth_crossover, tenancy_crossover
from repro.core.latency import (
    NetworkPath,
    ServiceModel,
    Tier,
    Workload,
    edge_offload_latency,
    on_device_latency,
)
from repro.core.manager import AdaptiveOffloadManager, EdgeServerState
from repro.core.multitenant import TenantStream, multitenant_edge_latency
from repro.core.split import LayerProfile, SplitPlanner
from repro.core.telemetry import TelemetrySnapshot

from .common import emit, mape, timed

# profiled-style service times (ms) per (workload, accelerator); see docstring
SERVICE_MS = {
    "mobilenetv2": {"tx2": 25.0, "orin": 8.0, "a2": 3.5, "rtx4070": 1.2},
    "inceptionv4": {"tx2": 150.0, "orin": 85.0, "a2": 28.0, "rtx4070": 9.0},
    "yolov8n": {"tx2": 50.0, "orin": 28.0, "a2": 19.0, "rtx4070": 6.0},
}
# effective edge parallelism k (paper §4.1: fitted per workload; heavy models
# occupy the whole A2, light ones batch well)
K_EDGE = {"mobilenetv2": 4.0, "inceptionv4": 1.0, "yolov8n": 1.0}
WORKLOAD_GFLOPS = {"mobilenetv2": 0.6e9, "inceptionv4": 6.3e9, "yolov8n": 8.7e9}
PAYLOADS = {  # (D_req, D_res) bytes — compressed-frame sizes by input res
    "mobilenetv2": (15_000, 1_000),
    "inceptionv4": (30_000, 1_000),
    "yolov8n": (90_000, 4_000),
}


def service_s(workload: str, hw: str) -> float:
    return SERVICE_MS[workload][hw] / 1e3


def _tiers(workload: str):
    dev_tx2 = Tier("tx2", service_s(workload, "tx2"), service_model=ServiceModel.DETERMINISTIC)
    dev_orin = Tier("orin", service_s(workload, "orin"), service_model=ServiceModel.DETERMINISTIC)
    edge_a2 = Tier("a2", service_s(workload, "a2"), parallelism_k=K_EDGE[workload],
                   service_model=ServiceModel.DETERMINISTIC)
    return dev_tx2, dev_orin, edge_a2


# ---------------------------------------------------------------------------
# Fig. 2: workload characteristics (3 DNNs x 2 devices vs A2 offload, 5 Mbps)
# ---------------------------------------------------------------------------


def fig2_workload_characteristics() -> float:
    errors = []
    net = NetworkPath(5e6 / 8)
    for wname in WORKLOAD_GFLOPS:
        dreq, dres = PAYLOADS[wname]
        wl = Workload(2.0, dreq, dres)
        tx2, orin, a2 = _tiers(wname)
        for dev in (tx2, orin):
            pred_dev = float(on_device_latency(wl, dev))
            sim_dev = S.simulate_on_device(
                wl.arrival_rate, S.Deterministic(dev.service_time_s), n=60_000,
                seed=hash(wname) % 1000,
            )
            errors.append(mape(pred_dev, sim_dev.mean))
        pred_edge = float(edge_offload_latency(wl, a2, net))
        sim_edge = S.simulate_offload(
            wl.arrival_rate, S.Deterministic(a2.service_time_s), int(a2.parallelism_k),
            bandwidth_Bps=net.bandwidth_Bps, req_bytes=dreq, res_bytes=dres,
            n=60_000, seed=hash(wname) % 997,
        )
        errors.append(mape(pred_edge, sim_edge.mean))
        (_, us) = (None, 0.0)
    overall = float(np.mean(errors))
    _, us = timed(lambda: edge_offload_latency(wl, a2, net))
    emit("fig2_workload_characteristics", us, f"mape_pct={overall:.2f}")
    return overall


# ---------------------------------------------------------------------------
# Fig. 3: LSTM / LLM — variable service -> M/M/1 formulation
# ---------------------------------------------------------------------------


def fig3_complex_models() -> float:
    errors = []
    net = NetworkPath(5e6 / 8)
    for name, (s_dev, s_edge, dreq, dres) in {
        "lstm": (0.020, 0.006, 4_000, 500),
        "llm": (0.800, 0.180, 2_000, 2_000),
    }.items():
        wl = Workload(0.8 if name == "llm" else 2.0, dreq, dres)
        dev = Tier("orin", s_dev, service_model=ServiceModel.EXPONENTIAL)
        edge = Tier("a2", s_edge, service_model=ServiceModel.EXPONENTIAL)
        pred_dev = float(on_device_latency(wl, dev))
        sim_dev = S.simulate_on_device(wl.arrival_rate, S.Exponential(s_dev), n=80_000, seed=11)
        pred_edge = float(edge_offload_latency(wl, edge, net))
        sim_edge = S.simulate_offload(
            wl.arrival_rate, S.Exponential(s_edge), 1, bandwidth_Bps=net.bandwidth_Bps,
            req_bytes=dreq, res_bytes=dres, n=80_000, seed=13,
        )
        errors += [mape(pred_dev, sim_dev.mean), mape(pred_edge, sim_edge.mean)]
        # offloading should win for the heavy LLM (paper: "even more pronounced")
        assert pred_edge < pred_dev or name == "lstm"
    overall = float(np.mean(errors))
    _, us = timed(lambda: on_device_latency(wl, dev))
    emit("fig3_complex_models", us, f"mape_pct={overall:.2f}")
    return overall


# ---------------------------------------------------------------------------
# Fig. 4: bandwidth sweeps and crossover points
# ---------------------------------------------------------------------------


def fig4_bandwidth_crossovers() -> dict:
    out = {}
    wname = "inceptionv4"
    dreq, dres = PAYLOADS[wname]
    wl = Workload(2.0, dreq, dres)
    for edge_hw in ("rtx4070", "a2"):
        for dev_hw in ("tx2", "orin"):
            dev = Tier(dev_hw, service_s(wname, dev_hw))
            edge = Tier(edge_hw, service_s(wname, edge_hw), parallelism_k=K_EDGE[wname])
            c = bandwidth_crossover(wl, dev, edge)
            key = f"{dev_hw}->{edge_hw}"
            out[key] = None if c.value is None else c.value * 8 / 1e6  # Mbps
    # the faster device needs MORE bandwidth before offloading pays (Fig 4a)
    (_, us) = timed(lambda: bandwidth_crossover(wl, Tier("tx2", service_s(wname, "tx2")),
                                                Tier("a2", service_s(wname, "a2"), parallelism_k=1)))
    ok = (out["tx2->rtx4070"] or 0) <= (out["orin->rtx4070"] or np.inf)
    emit("fig4_bandwidth_crossovers", us,
         f"tx2@rtx={out['tx2->rtx4070']:.2f}Mbps;orin@rtx={out['orin->rtx4070']:.2f}Mbps;ordered={ok}")
    return out


# ---------------------------------------------------------------------------
# Fig. 5a: collaborative (split) processing of a layered model
# ---------------------------------------------------------------------------


def fig5a_split_processing() -> float:
    wname = "mobilenetv2"
    # split processing ships UNCOMPRESSED tensors: SP0 = the raw 224x224x3
    # input (150 KB), later SPs = raw intermediate activations (paper §4.6:
    # "intermediate results of later layers grow in size")
    dreq, dres = 150_528, 1_000
    wl = Workload(2.0, dreq, dres)
    dev = Tier("orin", 1.0)  # per-layer services below are what matter
    edge = Tier("a2", 1.0, parallelism_k=K_EDGE[wname])
    # 8 layers; later layers have growing intermediate activations (paper §4.6)
    total_dev = service_s(wname, "orin")
    total_edge = service_s(wname, "a2")
    layers = [
        LayerProfile(
            dev_service_s=total_dev / 8,
            edge_service_s=total_edge / 8,
            out_bytes=120_000 + 45_000 * i,
        )
        for i in range(8)
    ]
    planner = SplitPlanner(layers, wl)
    net = NetworkPath(50e6 / 8)  # 50 Mbps (paper's split experiment)
    sweep = planner.sweep(dev, edge, net)
    plan = planner.plan(dev, edge, net)
    # validate three split points against simulation
    errs = []
    for idx in (0, 4, len(layers)):
        sp = planner.candidate(idx)
        pred = float(__import__("repro.core.split", fromlist=["split_latency"]).split_latency(
            wl, dev, edge, net, sp))
        sim = S.simulate_split(
            wl.arrival_rate,
            S.Deterministic(sp.dev_service_s) if sp.dev_service_s else S.Deterministic(0.0),
            S.Deterministic(sp.edge_service_s) if sp.edge_service_s else S.Deterministic(0.0),
            k_edge=int(K_EDGE[wname]), bandwidth_Bps=net.bandwidth_Bps,
            inter_bytes=sp.inter_bytes, res_bytes=wl.res_bytes, n=50_000, seed=idx,
        )
        errs.append(mape(pred, sim.mean))
    _, us = timed(lambda: planner.plan(dev, edge, net))
    emit("fig5a_split_processing", us,
         f"best_idx={plan.index};strategy={plan.strategy};mape_pct={np.mean(errs):.2f}")
    return float(np.mean(errs))


# ---------------------------------------------------------------------------
# Fig. 5b: request-rate sweep at 10 vs 20 Mbps
# ---------------------------------------------------------------------------


def fig5b_request_rate() -> dict:
    wname = "mobilenetv2"
    dreq, dres = PAYLOADS[wname]
    dev = Tier("orin", service_s(wname, "orin"), parallelism_k=1)
    edge = Tier("a2", service_s(wname, "a2"), parallelism_k=4)
    out = {}
    for mbps in (10, 20):
        net = NetworkPath(mbps * 1e6 / 8)
        lams = np.linspace(1, 120, 40)
        te = np.array([
            float(edge_offload_latency(Workload(l, dreq, dres), edge, net)) for l in lams
        ])
        td = np.array([float(on_device_latency(Workload(l, dreq, dres), dev)) for l in lams])
        finite = np.isfinite(te)
        wins = te[finite] < td[finite]
        out[mbps] = int(wins.sum())
    _, us = timed(lambda: on_device_latency(Workload(10, dreq, dres), dev))
    emit("fig5b_request_rate", us,
         f"offload_wins@10Mbps={out[10]}/40;@20Mbps={out[20]}/40;faster_net_wins_more={out[20] >= out[10]}")
    return out


# ---------------------------------------------------------------------------
# Fig. 5c: multi-tenancy sweep (co-located InceptionV4 apps)
# ---------------------------------------------------------------------------


def fig5c_multitenancy() -> int | None:
    wname = "inceptionv4"
    dreq, dres = PAYLOADS[wname]
    wl = Workload(2.0, dreq, dres)
    dev = Tier("tx2", service_s(wname, "tx2"))
    edge = Tier("a2", service_s(wname, "a2"), parallelism_k=K_EDGE[wname])
    net = NetworkPath(5e6 / 8)
    tenant = TenantStream(2.0, service_s(wname, "a2"))
    m_star = tenancy_crossover(wl, dev, edge, net, tenant, max_tenants=128)
    # validate the latency at m_star-1 and m_star+1 against simulation
    errs = []
    if m_star and m_star > 1:
        for m in (max(1, m_star - 2), m_star):
            pred = float(multitenant_edge_latency(wl, edge, net, [tenant] * m))
            sim = S.simulate_multitenant_offload(
                [(2.0, S.Deterministic(tenant.service_mean_s))] * m,
                max(1, int(edge.parallelism_k)), bandwidth_Bps=net.bandwidth_Bps,
                req_bytes=dreq, res_bytes=dres, n_per_stream=max(4000, 40000 // m), seed=m,
            )
            errs.append(mape(pred, sim.stream_mean(0)))
    _, us = timed(lambda: multitenant_edge_latency(wl, edge, net, [tenant] * 4))
    emit("fig5c_multitenancy", us,
         f"crossover_m={m_star};mape_pct={np.mean(errs):.2f}" if errs else f"crossover_m={m_star}")
    return m_star


# ---------------------------------------------------------------------------
# Fig. 6: adaptive manager under bandwidth dynamics (20 -> 10 -> 2 -> 20 Mbps)
# ---------------------------------------------------------------------------


def fig6_network_adaptation() -> list[str]:
    wname = "mobilenetv2"
    dreq, dres = PAYLOADS[wname]
    wl = Workload(10.0, dreq, dres)
    dev = Tier("tx2", service_s(wname, "tx2"))
    mgr = AdaptiveOffloadManager(dev)
    edge = EdgeServerState("a2", 1.0 / service_s(wname, "a2"), 10.0, service_s(wname, "a2"),
                           parallelism_k=K_EDGE[wname])
    schedule = [(t, bw) for t, bw in [(0, 20e6 / 8), (20, 10e6 / 8), (40, 2e6 / 8), (60, 20e6 / 8)]]
    strategies = []
    for t, bw in schedule:
        snap = TelemetrySnapshot(time_s=t, lam_dev=10.0, bandwidth_Bps=bw)
        strategies.append(mgr.decide(wl, snap, [edge]).strategy)
    _, us = timed(lambda: mgr.decide(wl, TelemetrySnapshot(0, 10.0, 2.5e6), [edge]))
    emit("fig6_network_adaptation", us, ";".join(strategies))
    return strategies


# ---------------------------------------------------------------------------
# Fig. 7: adaptive manager across multi-tenant edge servers
# ---------------------------------------------------------------------------


def fig7_multitenant_adaptation() -> list[str]:
    wname = "yolov8n"
    dreq, dres = PAYLOADS[wname]
    wl = Workload(10.0, dreq, dres)
    s_edge = service_s(wname, "a2")
    dev = Tier("tx2", service_s(wname, "tx2"))
    mgr = AdaptiveOffloadManager(dev)

    def edge(name, lam):
        return EdgeServerState(name, 1.0 / s_edge, lam, s_edge, parallelism_k=K_EDGE[wname])

    net = 40e6 / 8  # stable high-bandwidth link; load is what varies here
    phases = [
        ("t0", [edge("E1", 10 + 10), edge("E2", 30)]),
        ("t80", [edge("E1", 50 + 10), edge("E2", 30)]),
        ("t160", [edge("E1", 50), edge("E2", 50)]),
    ]
    targets = []
    for _, edges in phases:
        d = mgr.decide(wl, TelemetrySnapshot(0, 10.0, net), edges)
        targets.append(d.target_name)
    _, us = timed(lambda: mgr.decide(wl, TelemetrySnapshot(0, 10.0, net), phases[0][1]))
    emit("fig7_multitenant_adaptation", us, ";".join(targets))
    return targets


# ---------------------------------------------------------------------------
# Aggregate accuracy (the paper's 2.2% MAPE / 91.5% within 5% / 100% within 10%)
# ---------------------------------------------------------------------------


def model_accuracy_suite() -> dict:
    preds, obs = [], []
    rng = np.random.default_rng(0)
    scenarios = []
    for wname in WORKLOAD_GFLOPS:
        dreq, dres = PAYLOADS[wname]
        for lam in (1.0, 2.0, 5.0):
            for mbps in (5, 20):
                scenarios.append((wname, lam, mbps, dreq, dres))
    for i, (wname, lam, mbps, dreq, dres) in enumerate(scenarios):
        wl = Workload(lam, dreq, dres)
        net = NetworkPath(mbps * 1e6 / 8)
        tx2, orin, a2 = _tiers(wname)
        pred = float(edge_offload_latency(wl, a2, net))
        if not np.isfinite(pred):
            continue
        sim = S.simulate_offload(
            lam, S.Deterministic(a2.service_time_s), int(a2.parallelism_k),
            bandwidth_Bps=net.bandwidth_Bps, req_bytes=dreq, res_bytes=dres,
            n=60_000, seed=100 + i,
        )
        preds.append(pred)
        obs.append(sim.mean)
        pred_d = float(on_device_latency(wl, tx2))
        sim_d = S.simulate_on_device(lam, S.Deterministic(tx2.service_time_s), n=60_000, seed=200 + i)
        preds.append(pred_d)
        obs.append(sim_d.mean)
    preds, obs = np.array(preds), np.array(obs)
    rel = np.abs(preds - obs) / obs * 100
    out = {
        "mape_pct": float(rel.mean()),
        "within_5pct": float((rel <= 5).mean() * 100),
        "within_10pct": float((rel <= 10).mean() * 100),
        "n": int(len(rel)),
    }
    _, us = timed(lambda: edge_offload_latency(Workload(2, 1e5, 1e3), Tier("a2", 0.01), NetworkPath(1e6)))
    emit("model_accuracy_suite", us,
         f"mape_pct={out['mape_pct']:.2f};within5={out['within_5pct']:.1f};within10={out['within_10pct']:.1f};n={out['n']}")
    return out
